#!/usr/bin/env python3
"""CI docs gate: every intra-repo markdown link must resolve to a real file.

Scans all tracked-ish ``*.md`` files for ``[text](target)`` links, skips
external schemes (http/https/mailto) and pure anchors, and fails listing
every target whose path (relative to the linking file) does not exist.

Also scans ``*.py`` sources for bare markdown-file mentions (docstrings
and comments routinely point readers at docs — e.g. "see EXPERIMENTS.md
§Perf") and fails on any that resolve against neither the repo root nor the
referencing file's own directory: a doc a source file promises must exist.

    python tools/check_md_links.py [root]
"""
import pathlib
import re
import sys

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".hypothesis", ".venv",
             "node_modules"}
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = re.compile(r"^([a-zA-Z][a-zA-Z0-9+.-]*:)")
# bare doc mentions in source: path-ish tokens ending in ".md". The leading
# character class rejects glob/regex fragments like "*.md" or "\.md".
PY_MD_REF = re.compile(r"(?<![\w./\\*-])[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    bad = []
    checked = 0
    for md in sorted(root.rglob("*.md")):
        if SKIP_DIRS & set(md.parts):
            continue
        for m in LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if EXTERNAL.match(target) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0].split("?", 1)[0]
            if not path:
                continue
            checked += 1
            if not (md.parent / path).exists():
                bad.append(f"{md.relative_to(root)}: broken link -> {target}")
    py_checked = 0
    for py in sorted(root.rglob("*.py")):
        if SKIP_DIRS & set(py.parts):
            continue
        for m in PY_MD_REF.finditer(py.read_text(encoding="utf-8")):
            ref = m.group(0)
            py_checked += 1
            if not ((root / ref).exists() or (py.parent / ref).exists()):
                bad.append(f"{py.relative_to(root)}: dangling doc "
                           f"reference -> {ref}")
    if bad:
        print("\n".join(bad))
        return 1
    print(f"check_md_links: OK ({checked} intra-repo links resolve, "
          f"{py_checked} doc references from sources)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
