"""Serve a (reduced) assigned architecture behind the FAME agents: batched
requests through the continuous-batching engine as the agents' LLM backend,
on the serving fast path (bucketed prefill + chunked on-device decode).

    PYTHONPATH=src python examples/serve_agents.py --arch recurrentgemma-9b
"""
import argparse
import time

from repro.apps import research_summary as rs
from repro.configs.registry import ARCHS
from repro.core.config import CONFIGS
from repro.core.llm import JaxLLM, rates_for_arch
from repro.core.runtime import FameRuntime
from repro.serving.engine import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--chunk", type=int, default=16,
                    help="decode tokens per jit'd inner loop")
    ap.add_argument("--block-w", type=int, default=256,
                    help="decode-attention KV block (cache capacity aligns to it)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--cache-mode", default="dense", choices=("dense", "paged"),
                    help="paged = radix prefix sharing: KV page pool on "
                         "full-attention archs, per-prefix recurrent-state "
                         "snapshots on stateful archs; agent turns that "
                         "re-send the conversation prefix skip its prefill")
    ap.add_argument("--spec-len", type=int, default=0,
                    help="speculative decode: max draft tokens per verify "
                         "step from the prompt n-gram lookup drafter "
                         "(0 = off); copy-heavy agent outputs decode "
                         "several tokens per forward")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512)
    engine = ServingEngine(cfg, num_slots=args.slots, capacity=192,
                           engine_cfg=EngineConfig(decode_chunk=args.chunk,
                                                   block_w=args.block_w,
                                                   cache_mode=args.cache_mode,
                                                   spec_len=args.spec_len))
    print(f"engine up: arch={cfg.name} slots={args.slots} "
          f"buckets={list(engine.buckets)} chunk={args.chunk} "
          f"cache={args.cache_mode} spec_len={args.spec_len}")

    # 1) raw batched serving
    t0 = time.time()
    reqs = [engine.submit(f"request {i}: summarize the introduction of paper {i}",
                          max_new_tokens=16, temperature=args.temperature,
                          top_k=args.top_k) for i in range(args.requests)]
    engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(r.output_tokens for r in reqs)
    stats = engine.stats()
    print(f"batched serving: {args.requests} requests, {toks} tokens, "
          f"{dt:.1f}s wall ({toks / dt:.1f} tok/s on CPU interpret)")
    print(f"fast path: {stats['prefill_compiles']} prefill compiles over "
          f"{len(stats['prefill_buckets'])} buckets, "
          f"{stats['host_syncs_per_token']:.3f} host syncs/token "
          f"({stats['host_syncs']} syncs / {stats['decode_tokens']} decode tokens)")
    if args.cache_mode == "paged":
        kind = ("shared pages" if "pages_total" in stats
                else "restored state snapshots")
        pool = (f"{stats['pages_free']}/{stats['pages_total']} pages free"
                if "pages_total" in stats else
                f"{stats['snapshots_free']}/{stats['snapshots_total']} "
                f"snapshot rows free")
        print(f"prefix sharing: {stats['prefix_hit_rate']:.0%} of prompt "
              f"tokens served from {kind} "
              f"({stats['prefix_hit_tokens']}/{stats['prompt_tokens']}), "
              f"{stats['radix_nodes']} radix nodes, {pool}")

    # 2) the same engine as the agents' LLM backend (one workflow invocation)
    rt = FameRuntime(config=CONFIGS["M+C"], max_iterations=1)
    backend = JaxLLM(engine, max_new_tokens=8,
                     latency=rates_for_arch(args.arch),
                     temperature=args.temperature, top_k=args.top_k)
    for role in ("planner", "actor", "evaluator"):
        rt.set_llm(role, backend)
    rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
    res = rt.run_session("serve-demo", rs.queries("P1")[:1])
    tr = res.traces[0]
    i_tok, o_tok = tr.llm_tokens()
    print(f"agent workflow on JaxLLM: status={res.statuses[0]} "
          f"llm_calls={tr.count('llm')} in_tok={i_tok} out_tok={o_tok}")
    print(f"serving stats after agents: {backend.serving_stats()}")
    print("(untrained weights -> workflow outcome is expected to DNF; the "
          "point is the full tokenize->prefill->decode serving path under "
          "the agents)")


if __name__ == "__main__":
    main()
