"""Serve a (reduced) assigned architecture behind concurrent FAME workflows
through the session-oriented serving API: N workflows open N sessions on one
``LLMServer``, every round their Planner/Actor/Evaluator turns are submitted
as non-blocking handles BEFORE any is drained — so they co-batch inside the
same engine steps — and each session's next turn restores the previous
turn's end-of-generation state instead of re-prefilling the conversation.

    PYTHONPATH=src python examples/serve_agents.py --arch recurrentgemma-9b
"""
import argparse
import time

from repro.apps import research_summary as rs
from repro.configs.registry import ARCHS
from repro.core.config import CONFIGS
from repro.fame import WorkflowServingRuntime
from repro.serving.server import EngineConfig, LLMServer, SamplingParams

ROLES = [("planner", "Plan the next step toward the goal."),
         ("actor", "Act: run the planned tool call."),
         ("evaluator", "Evaluate the output; pass or retry.")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--workflows", type=int, default=3,
                    help="concurrent agent workflows (one session each)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="Planner/Actor/Evaluator rounds per workflow")
    ap.add_argument("--chunk", type=int, default=16,
                    help="decode tokens per jit'd inner loop")
    ap.add_argument("--block-w", type=int, default=256,
                    help="decode-attention KV block (cache capacity aligns to it)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--cache-mode", default="paged", choices=("dense", "paged"),
                    help="paged = radix prefix sharing + session tail reuse: "
                         "KV page pool on full-attention archs, per-prefix "
                         "recurrent-state snapshots on stateful archs; turns "
                         "that extend their conversation skip its prefill")
    ap.add_argument("--spec-len", type=int, default=0,
                    help="speculative decode: max draft tokens per verify "
                         "step from the prompt n-gram lookup drafter "
                         "(0 = off); copy-heavy agent outputs decode "
                         "several tokens per forward")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512)
    server = LLMServer(cfg, num_slots=args.slots, capacity=512,
                       engine_cfg=EngineConfig(decode_chunk=args.chunk,
                                               block_w=args.block_w,
                                               cache_mode=args.cache_mode,
                                               spec_len=args.spec_len))
    print(f"server up: arch={cfg.name} slots={args.slots} "
          f"buckets={list(server.engine.buckets)} chunk={args.chunk} "
          f"cache={args.cache_mode} spec_len={args.spec_len}")

    # 1) N concurrent workflows: one session per workflow, handles co-batch
    params = SamplingParams(max_new_tokens=10, temperature=args.temperature,
                            top_k=args.top_k)
    sessions = [server.open_session() for _ in range(args.workflows)]
    convs = [f"System: cooperating agents, workflow {w}. Keep tool calls "
             f"minimal, cite evidence. " for w in range(args.workflows)]
    t0 = time.time()
    toks = turns = 0
    for r in range(args.rounds):
        for role, ask in ROLES:
            # submit EVERY workflow's turn before draining any — that is
            # what lets the engine co-batch them in the same decode chunks
            handles = [sessions[w].submit(convs[w] + f"[{role} r{r}] {ask} ",
                                          params)
                       for w in range(args.workflows)]
            if r == 0 and role == "planner":
                # streaming demo on the first turn of workflow 0
                print("streaming turn 0.0: ", end="")
                for piece in handles[0].stream():
                    print(repr(piece), end=" ")
                print()
            server.run_until_idle()
            for w, h in enumerate(handles):
                convs[w] = sessions[w].text
                toks += h.request.output_tokens
                turns += 1
    dt = time.time() - t0
    stats = server.stats()
    print(f"co-batched serving: {args.workflows} workflows x {turns // max(args.workflows, 1)} "
          f"turns, {toks} tokens, {dt:.1f}s wall ({toks / dt:.1f} tok/s on CPU)")
    print(f"fast path: {stats['prefill_compiles']} prefill compiles over "
          f"{len(stats['prefill_buckets'])} buckets, "
          f"{stats['host_syncs_per_token']:.3f} host syncs/token, "
          f"{stats['active_slots_per_step']:.2f} active slots/engine step")
    print(f"sessions: {stats['sessions_opened']} opened, "
          f"{stats['session_turns']} turns, "
          f"{stats['turn_prefix_hits']} admitted off the retained tail, "
          f"{stats['stream_chunks']} stream chunks")
    if args.cache_mode == "paged":
        kind = ("shared pages" if "pages_total" in stats
                else "restored state snapshots")
        pool = (f"{stats['pages_free']}/{stats['pages_total']} pages free"
                if "pages_total" in stats else
                f"{stats['snapshots_free']}/{stats['snapshots_total']} "
                f"snapshot rows free")
        print(f"prefix sharing: {stats['prefix_hit_rate']:.0%} of prompt "
              f"tokens served from {kind} "
              f"({stats['prefix_hit_tokens']}/{stats['prompt_tokens']}), "
              f"{stats['radix_nodes']} radix nodes, {pool}")

    # 2) the same server under the FAME workflow runtime (docs/fame.md):
    #    one persistent session per invocation chain (memory == tail reuse),
    #    oracle-guided decisions, tool results injected through the cache
    rt = WorkflowServingRuntime(
        config=CONFIGS["M+C"], server=server,
        params=SamplingParams(max_new_tokens=8,
                              temperature=args.temperature, top_k=args.top_k))
    for role, oracle in rs.build_oracles().items():
        rt.set_llm(role, oracle)
    rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
    res = rt.run_session("serve-demo", rs.APP.queries("P1")[:2])
    m = rt.meter.summary()
    print(f"FAME workflow on the server: statuses={res.statuses} "
          f"turns={m['turns']} injections={m['injections']} "
          f"billed_in={m['billed_in_tokens']} of {m['prompt_tokens']} "
          f"prompt tokens ({m['continuation_turns']} continuation turns "
          f"reused the session tail)")
    print("(decisions are oracle-guided over the served conversation — "
          "untrained weights decode noise — but every agent turn and tool "
          "injection above was a real tokenize->prefill->decode request)")


if __name__ == "__main__":
    main()
