"""End-to-end training driver: train a ~100M-class reduced model for a few
hundred steps on CPU with the full production loop — sharded(1×1) params,
microbatch accumulation, async checkpointing, fault-tolerant supervision.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-3b --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step_dir, restore
from repro.configs.registry import ARCHS
from repro.models import Model
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/fame_train_ckpt")
    args = ap.parse_args()

    base = ARCHS[args.arch]
    n_layers = (args.layers if len(base.pattern) == 1
                else len(base.pattern) * max(1, args.layers // len(base.pattern)))
    cfg = base.reduced(dtype="float32", param_dtype="float32",
                       d_model=args.d_model, num_heads=8, head_dim=32,
                       d_ff=4 * args.d_model if base.d_ff else 0,
                       vocab_size=2048, num_layers=n_layers,
                       rglru_dim=args.d_model if base.rglru_dim else 0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M layers={cfg.num_layers}")

    data = SyntheticLM(DataConfig(global_batch=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size), cfg)
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=20,
                                       total_steps=args.steps),
                       accum_steps=args.accum)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    start = 0
    if latest_step_dir(args.ckpt_dir):
        (params, opt), start = restore(args.ckpt_dir, (params, opt))
        print(f"resumed from checkpoint at step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.batch_at(step)
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            toks = args.batch * args.seq * (step - start + 1)
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} "
                  f"tok/s={toks / (time.time() - t0):.0f}")
        if step and step % 50 == 0:
            ckpt.save(step, (params, opt))
    ckpt.save(args.steps, (params, opt))
    ckpt.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
