"""Log-analytics app (§4.1): error filtering → aggregation → visualization,
showing S3 file handling and the artifacts left in the object store.

    PYTHONPATH=src python examples/log_analytics.py [--log L1] [--config M+C]
"""
import argparse

from repro.apps import log_analytics as la
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log", default="L1", choices=["L1", "L2", "L3"])
    ap.add_argument("--config", default="M+C", choices=sorted(CONFIGS))
    args = ap.parse_args()

    rt = FameRuntime(config=CONFIGS[args.config])
    for role, o in la.build_oracles().items():
        rt.set_llm(role, o)
    rt.deploy_mcp(la.APP.servers, la.APP.sources)

    meta = la.data.LOGS[args.log]
    print(f"log: {meta['path']} ({meta['kind']}, {meta['kb']}KB), "
          f"errors: {meta['errors']}")
    res = rt.run_session(f"la-{args.log}", la.APP.queries(args.log))
    for qi, (resp, st) in enumerate(zip(res.responses, res.statuses)):
        print(f"\nQ{qi + 1} [{st}]: {resp[:200]}")
    print("\nobject-store artifacts:")
    for bucket in ("fame-timestamps", "fame-plots", "fame-mcp-cache"):
        keys = rt.objects.list(bucket)
        print(f"  s3://{bucket}/: {len(keys)} objects "
              f"{keys[:3] if keys else ''}")


if __name__ == "__main__":
    main()
