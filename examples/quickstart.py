"""Quickstart: deploy a FAME stack and run one multi-turn agentic session.

    PYTHONPATH=src python examples/quickstart.py [--config M+C] [--app RS]

``--llm oracle`` (default) drives the workflow with the deterministic
scripted oracle; ``--llm jax`` hosts the agents on the real serving stack —
an ``repro.serving.server.LLMServer`` session per agent role (tokenize →
prefill → decode on a reduced architecture; untrained weights, so workflow
outcomes DNF — the point is the serving path).
"""
import argparse

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime


def build_jax_backend(arch: str):
    """FAME agents on the session-oriented serving API (LLMServer)."""
    from repro.configs.registry import ARCHS
    from repro.core.llm import JaxLLM, rates_for_arch
    from repro.serving.server import EngineConfig, LLMServer

    cfg = ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                              vocab_size=512)
    server = LLMServer(cfg, num_slots=4, capacity=512,
                       engine_cfg=EngineConfig(cache_mode="paged"))
    return server, JaxLLM(server, max_new_tokens=8,
                          latency=rates_for_arch(arch))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="M+C", choices=sorted(CONFIGS))
    ap.add_argument("--app", default="RS", choices=["RS", "LA"])
    ap.add_argument("--fusion", default="singleton",
                    choices=["singleton", "consolidated"])
    ap.add_argument("--llm", default="oracle", choices=["oracle", "jax"],
                    help="oracle: scripted deterministic LLM; jax: the real "
                         "serving stack behind an LLMServer session per role")
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help="architecture for --llm jax")
    args = ap.parse_args()

    app = {"RS": rs, "LA": la}[args.app]
    rt = FameRuntime(config=CONFIGS[args.config], fusion_mode=args.fusion)
    server = None
    if args.llm == "jax":
        server, backend = build_jax_backend(args.arch)
        for role in app.build_oracles():
            rt.set_llm(role, backend)
    else:
        for role, oracle in app.build_oracles().items():
            rt.set_llm(role, oracle)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)

    print(f"=== FAME quickstart: app={args.app} config={args.config} "
          f"fusion={args.fusion} llm={args.llm} ===")
    print(f"deployed functions: {sorted(rt.platform.functions)}")
    for w in rt._wrapped:
        print(f"--- generated wrapper for MCP server {w.server.name!r} ---")
        print(w.wrapper_source.splitlines()[2])

    inp = app.APP.inputs[0]
    res = rt.run_session(f"quickstart-{inp}", app.APP.queries(inp))
    for qi, (q, resp, status) in enumerate(
            zip(app.APP.queries(inp), res.responses, res.statuses)):
        tr = res.traces[qi]
        i_tok, o_tok = tr.llm_tokens()
        print(f"\nQ{qi + 1}: {q[:78]}")
        print(f"  status={status} in_tokens={i_tok} out_tokens={o_tok} "
              f"tool_calls={tr.count('mcp')}")
        print(f"  answer: {resp[:120]}...")
    print(f"\ncache hits: {rt.cache.hits}  "
          f"memory entries: {len(rt.memory.recall(f'quickstart-{inp}'))}")
    print("cost breakdown (cents):",
          {k: round(sum(t.cost_breakdown()[k] for t in res.traces), 3)
           for k in ("llm_cents", "faas_agent_cents", "faas_mcp_cents")})
    if server is not None:
        st = server.stats()
        print("serving stats:",
              {k: st[k] for k in ("sessions_opened", "session_turns",
                                  "turn_prefix_hits", "decode_tokens",
                                  "host_syncs_per_token",
                                  "active_slots_per_step") if k in st})


if __name__ == "__main__":
    main()
