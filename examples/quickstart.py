"""Quickstart: deploy a FAME stack and run one multi-turn agentic session.

    PYTHONPATH=src python examples/quickstart.py [--config M+C] [--app RS]
"""
import argparse

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="M+C", choices=sorted(CONFIGS))
    ap.add_argument("--app", default="RS", choices=["RS", "LA"])
    ap.add_argument("--fusion", default="singleton",
                    choices=["singleton", "consolidated"])
    args = ap.parse_args()

    app = {"RS": rs, "LA": la}[args.app]
    rt = FameRuntime(config=CONFIGS[args.config], fusion_mode=args.fusion)
    for role, oracle in app.build_oracles().items():
        rt.set_llm(role, oracle)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)

    print(f"=== FAME quickstart: app={args.app} config={args.config} "
          f"fusion={args.fusion} ===")
    print(f"deployed functions: {sorted(rt.platform.functions)}")
    for w in rt._wrapped:
        print(f"--- generated wrapper for MCP server {w.server.name!r} ---")
        print(w.wrapper_source.splitlines()[2])

    inp = app.APP.inputs[0]
    res = rt.run_session(f"quickstart-{inp}", app.APP.queries(inp))
    for qi, (q, resp, status) in enumerate(
            zip(app.APP.queries(inp), res.responses, res.statuses)):
        tr = res.traces[qi]
        i_tok, o_tok = tr.llm_tokens()
        print(f"\nQ{qi + 1}: {q[:78]}")
        print(f"  status={status} in_tokens={i_tok} out_tokens={o_tok} "
              f"tool_calls={tr.count('mcp')}")
        print(f"  answer: {resp[:120]}...")
    print(f"\ncache hits: {rt.cache.hits}  "
          f"memory entries: {len(rt.memory.recall(f'quickstart-{inp}'))}")
    print("cost breakdown (cents):",
          {k: round(sum(t.cost_breakdown()[k] for t in res.traces), 3)
           for k in ("llm_cents", "faas_agent_cents", "faas_mcp_cents")})


if __name__ == "__main__":
    main()
