"""Research-paper-summarization app (§4.1) across all five memory configs —
reproduces the paper's Fig. 3/4 behaviour interactively.

    PYTHONPATH=src python examples/research_summary.py [--paper P1]
"""
import argparse

from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", default="P1", choices=["P1", "P2", "P3"])
    args = ap.parse_args()

    print(f"paper: {rs.data.title_of(args.paper)!r}")
    print(f"{'config':6s} {'Q1':>14s} {'Q2':>14s} {'Q3':>14s} "
          f"{'in_tok':>8s} {'e2e_s':>7s}")
    for cname in ["E", "N", "C", "M", "M+C"]:
        rt = FameRuntime(config=CONFIGS[cname])
        for role, o in rs.build_oracles().items():
            rt.set_llm(role, o)
        rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
        res = rt.run_session(f"s-{args.paper}", rs.queries(args.paper))
        cells = []
        for st, tr in zip(res.statuses, res.traces):
            faas = [s for s in tr.spans if s.kind == "faas"]
            dur = (max(s.t_end for s in faas) - min(s.t_start for s in faas)
                   if faas else 0)
            cells.append(f"{'OK' if st == 'SUCCEEDED' else 'DNF'}/{dur:5.1f}s")
        tok = sum(t.llm_tokens()[0] for t in res.traces)
        tot = sum(max((s.t_end for s in t.spans if s.kind == 'faas'), default=0)
                  - min((s.t_start for s in t.spans if s.kind == 'faas'), default=0)
                  for t in res.traces)
        print(f"{cname:6s} {cells[0]:>14s} {cells[1]:>14s} {cells[2]:>14s} "
              f"{tok:8d} {tot:7.1f}")


if __name__ == "__main__":
    main()
