"""Overload / load-shedding benchmark for the always-on serving stack.

Drives an ``LLMServer(pump=True, overload=OverloadPolicy(...))`` — the
standing-service deployment shape (PAPER.md's always-warm Lambda analogue)
— with open-loop arrivals over many short-lived sessions and measures how
the overload controls degrade service when demand exceeds capacity:

* **arrivals** — Poisson (seeded exponential inter-arrivals, ``--rate``)
  or trace-driven (``--trace burst`` built-in bursty trace, or a JSON file
  of arrival-time offsets in seconds). Each arrival opens its own session,
  submits one turn, and closes the session when the request reaches a
  terminal status — so the retained-tail pool churns the way a fleet of
  real conversations would.
* **priority mix** — ~30% of arrivals are high-priority (``priority=1``,
  interactive SLO); the rest are low-priority batch
  (``priority=0``). Under overload the policy sheds and preempts LOW to
  protect HIGH: bounded admission refuses (typed ``OverloadError``) or
  displaces the youngest queued low request (typed ``ShedError``), and a
  queued high request preempts a running low slot at the chunk boundary.
* **directed preemption probe** — after the open-loop phase drains, two
  long low-priority decodes are parked in every slot and a high-priority
  request submitted on top, forcing a preemption deterministically; the
  preempted request's greedy output is then replayed uncontended and must
  be **bit-identical** (resume re-prefills prompt + the k pre-generated
  tokens and continues the RNG chain at fold_in(key, k)).

Reported: per-class TTFT p50/p99 (``first_token_s`` — preserved across
preemption), time-per-output-token, goodput (completed-within-SLO requests
and their tokens per wall second), shed / preempt / timeout / dead-letter
counts, and peak queue depth/age gauges sampled during the run:

    PYTHONPATH=src python benchmarks/load_bench.py [--smoke] [--chaos]

Acceptance gates (ISSUE 8, CI runs ``--smoke`` with and without
``--chaos``): the server stays live under overload (every submitted
request reaches a terminal typed status — nothing stranded), overload
control actually engaged (sheds + admission rejections + preemptions > 0),
shed requests carry a typed ``OverloadError``/``ShedError`` (not a bare
failure), high-priority p99 TTFT stays under the gate while low-priority
degrades, and the preempted-then-resumed greedy output is bit-identical.
``--chaos`` layers the PR-6 seeded ``FaultInjector`` on top of overload
and keeps the same gates with faults actually firing (faults > 0).
"""
from __future__ import annotations

import argparse
import json
import random
import threading
import time

from _artifact import write_artifact


def make_arrivals(args) -> list:
    """Arrival-time offsets (seconds from t0), sorted ascending."""
    if args.trace == "poisson":
        rng = random.Random(args.seed)
        t, out = 0.0, []
        for _ in range(args.requests):
            t += rng.expovariate(args.rate)
            out.append(t)
        return out
    if args.trace == "burst":
        # deterministic bursty trace: requests arrive in 3 tight clumps
        # (t = 0, 0.5, 1.0) so the admission queue fills, drains, refills
        per = max(args.requests // 3, 1)
        out = []
        for b in range(3):
            n = per if b < 2 else args.requests - 2 * per
            out.extend(b * 0.5 + i * 0.002 for i in range(n))
        return sorted(out[:args.requests])
    with open(args.trace) as f:                  # JSON list of offsets
        offs = sorted(float(x) for x in json.load(f))
    return offs[:args.requests] if args.requests else offs


def pctl(vals, q):
    if not vals:
        return 0.0
    vals = sorted(vals)
    return vals[min(int(q * (len(vals) - 1) + 0.5), len(vals) - 1)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=300,
                    help="total arrivals (each its own session)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate, req/s (open loop)")
    ap.add_argument("--trace", default="poisson",
                    help="'poisson', 'burst' (built-in bursty trace), or a "
                         "path to a JSON list of arrival offsets in seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=8,
                    help="concurrent submitter threads (independent open-"
                         "loop clients; keeps arrivals from self-throttling "
                         "on the pump's command round-trip)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a FleetServer of N replicas instead "
                         "of a single LLMServer (slots / queue-depth are "
                         "PER replica; see benchmarks/fleet_bench.py for "
                         "the dedicated 1-vs-N comparison)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="OverloadPolicy.max_queue_depth")
    ap.add_argument("--deadline-lo", type=float, default=None,
                    help="deadline_s for low-priority arrivals (enables "
                         "deadline-aware shedding of the batch class)")
    ap.add_argument("--slo-ttft", type=float, default=30.0,
                    help="per-request TTFT SLO used for goodput accounting")
    ap.add_argument("--hi-ttft-gate", type=float, default=30.0,
                    help="gate: high-priority p99 TTFT must stay under this")
    ap.add_argument("--out", default="results/load_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI robustness gating")
    ap.add_argument("--chaos", action="store_true",
                    help="layer seeded transient faults on top of overload")
    ap.add_argument("--fault-rate", type=float, default=0.05)
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots, args.queue_depth = 36, 2, 8
        args.max_new, args.capacity = 8, 256
        # hold PER-REPLICA offered load constant so the overload controls
        # still engage when the fleet doubles service capacity
        args.requests *= args.replicas

    from repro.configs.registry import ARCHS
    from repro.serving.faults import OverloadError
    from repro.serving.server import (EngineConfig, FaultInjector, LLMServer,
                                      OverloadPolicy, RetryPolicy,
                                      SamplingParams)

    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512, d_model=256, num_heads=8,
                                   head_dim=32, d_ff=512, num_layers=4)
    injector = None
    if args.chaos:
        r = args.fault_rate
        injector = FaultInjector(seed=args.seed,
                                 rates={"decode": r, "extend_paged": r,
                                        "pool.alloc": r})
    policy = OverloadPolicy(max_queue_depth=args.queue_depth, preempt=True,
                            shed_on_deadline=True)
    server_kw = dict(
        num_slots=args.slots, capacity=args.capacity, seed=args.seed,
        engine_cfg=EngineConfig(cache_mode="paged", page_size=args.page_size,
                                decode_chunk=args.chunk),
        injector=injector, overload=policy,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.005),
        pump=True)
    if args.replicas > 1:
        # same per-replica knobs, fronted by the fleet router: sessions
        # stay sticky, overload spills across replicas before shedding
        from repro.serving.fleet import FleetServer
        server = FleetServer(cfg, num_replicas=args.replicas, **server_kw)
    else:
        server = LLMServer(cfg, **server_kw)

    rng = random.Random(args.seed + 1)
    arrivals = make_arrivals(args)
    # greedy everywhere: the bit-identity gates are RNG-independent and the
    # outputs replayable on any reference engine
    lo_sp = SamplingParams(max_new_tokens=args.max_new, temperature=0.0,
                           priority=0, deadline_s=args.deadline_lo)
    hi_sp = SamplingParams(max_new_tokens=args.max_new, temperature=0.0,
                           priority=1)

    inflight = []                   # (handle, session, class, submit_off)
    done = []
    rejected = {"hi": 0, "lo": 0}   # typed admission refusals per class
    gauges = {"queue_depth": 0, "queue_age_s": 0.0}
    io_lock = threading.Lock()
    draining = threading.Event()

    def reaper():
        """Close each arrival's session the moment its turn is terminal
        (so hundreds of sessions churn the tail pool instead of pinning
        it), and sample the queue-shape gauges while load is on."""
        while True:
            with io_lock:
                still = []
                for rec in inflight:
                    h, sess = rec[0], rec[1]
                    if h.request.finished:
                        sess.close()
                        done.append(rec)
                    else:
                        still.append(rec)
                inflight[:] = still
                idle = draining.is_set() and not inflight
            st = server.stats()
            gauges["queue_depth"] = max(gauges["queue_depth"],
                                        st["queued_requests"])
            gauges["queue_age_s"] = max(gauges["queue_age_s"],
                                        st["queue_age_max_s"])
            if idle:
                return
            time.sleep(0.02)

    # throwaway turns to absorb jit compiles before the clock starts (one
    # per replica when fronted by a fleet — each engine compiles its own)
    if args.replicas > 1:
        for r in server.replicas:
            r.server.submit("warmup " * 4,
                            SamplingParams(max_new_tokens=4)).result()
    else:
        server.submit("warmup " * 4,
                      SamplingParams(max_new_tokens=4)).result()

    # the full arrival schedule, decided up front (deterministic for a
    # given seed) and sharded round-robin across independent client
    # threads: each arrival = (offset_s, class, prompt)
    plan = []
    for i, off in enumerate(arrivals):
        is_hi = rng.random() < 0.3
        plan.append((off, "hi" if is_hi else "lo",
                     (f"[{'hi' if is_hi else 'lo'} {i}] summarize incident "
                      f"{i % 7} in the {i % 5} region. ") * 2))

    def client(shard):
        for off, cls, prompt in shard:
            now = time.perf_counter() - t0
            if off > now:
                time.sleep(off - now)
            sess = server.open_session()
            try:
                h = sess.submit(prompt, hi_sp if cls == "hi" else lo_sp)
            except OverloadError:
                with io_lock:
                    rejected[cls] += 1
                sess.close()
                continue
            with io_lock:
                inflight.append((h, sess, cls,
                                 time.perf_counter() - t0))

    reap = threading.Thread(target=reaper, daemon=True)
    reap.start()
    t0 = time.perf_counter()
    clients = [threading.Thread(target=client,
                                args=(plan[c::args.clients],), daemon=True)
               for c in range(args.clients)]
    for c in clients:
        c.start()
    for c in clients:
        c.join()
    draining.set()
    server.run_until_idle()
    reap.join()
    wall = time.perf_counter() - t0
    st = server.stats()

    # ---- directed preemption probe + bit-identity gate ---------------------
    # park a long low-priority decode in every slot, then submit a
    # high-priority request: with no free slot and a strict priority gap the
    # scheduler MUST preempt one low slot at its next chunk boundary
    long_sp = SamplingParams(max_new_tokens=48, temperature=0.0, priority=0)
    if args.replicas > 1:
        # park straight onto every replica's slots (bypassing the router —
        # least-loaded placement is noisy right after the open-loop phase)
        # so the fleet has no idle slot anywhere when the probe arrives;
        # longer decodes than the single-server probe because the probe's
        # own fleet placement (digest refresh + routing) takes extra pump
        # round-trips that the parked jobs must outlive
        long_sp = SamplingParams(max_new_tokens=128, temperature=0.0,
                                 priority=0)
        parked = [r.server.submit(f"long batch job {r.idx}-{s} " * 3,
                                  long_sp)
                  for r in server.replicas for s in range(args.slots)]
    else:
        parked = [server.submit(f"long batch job {i} " * 3, long_sp)
                  for i in range(args.slots)]
    deadline = time.perf_counter() + 60.0
    while (any(p.request.status != "running" for p in parked)
           and time.perf_counter() < deadline):
        time.sleep(0.005)
    probe_hi = server.submit("interactive probe",
                             SamplingParams(max_new_tokens=8, temperature=0.0,
                                            priority=5))
    probe_hi.result()
    for p in parked:
        p.result()
    victims = [p for p in parked if p.request.preempted > 0]
    probe_preempted = len(victims)
    # uncontended greedy replay of each victim's ORIGINAL prompt tokens on
    # the (now idle) server: resume must have been bit-identical
    probe_identical = True
    for v in victims:
        ref = server.submit(
            "", long_sp,
            token_ids=list(v.request._ids[:v.request._orig_plen]))
        if ref.result() != v.request.output_text:
            probe_identical = False
    probe_stats = server.stats()

    # ---- metrics -----------------------------------------------------------
    by_cls = {"hi": [], "lo": []}
    for h, _sess, cls, _off in done:
        by_cls[cls].append(h.request)
    statuses = [h.request.status for h, *_ in done]
    terminal = {"completed", "cancelled", "timed_out", "failed", "shed"}
    shed_reqs = [h.request for h, *_ in done if h.request.status == "shed"]
    sheds_typed = all(isinstance(r.error, OverloadError) for r in shed_reqs)

    def cls_metrics(reqs):
        ttft = [r.first_token_s for r in reqs if r.first_token_s > 0]
        comp = [r for r in reqs if r.status == "completed"]
        tpot = [r.decode_s / r.output_tokens for r in comp
                if r.output_tokens and r.decode_s > 0]
        good = [r for r in comp
                if 0 < r.first_token_s <= args.slo_ttft]
        return {
            "requests": len(reqs),
            "completed": len(comp),
            "shed": sum(1 for r in reqs if r.status == "shed"),
            "timed_out": sum(1 for r in reqs if r.status == "timed_out"),
            "failed": sum(1 for r in reqs if r.status == "failed"),
            "preempted": sum(1 for r in reqs if r.preempted),
            "ttft_p50_s": round(pctl(ttft, 0.50), 5),
            "ttft_p99_s": round(pctl(ttft, 0.99), 5),
            "tpot_mean_s": round(sum(tpot) / max(len(tpot), 1), 6),
            "goodput_req_s": round(len(good) / wall, 3),
            "goodput_tok_s": round(sum(r.output_tokens for r in good) / wall,
                                   2),
        }

    hi_m, lo_m = cls_metrics(by_cls["hi"]), cls_metrics(by_cls["lo"])
    overload_events = (st["shed_requests"] + st["preemptions"]
                      + rejected["hi"] + rejected["lo"])
    result = {
        "bench": "load_serving",
        "arch": args.arch,
        "trace": args.trace,
        "requests": args.requests,
        "rate_req_s": args.rate,
        "replicas": args.replicas,
        "num_slots": args.slots,
        "queue_depth": args.queue_depth,
        "max_new_tokens": args.max_new,
        "wall_s": round(wall, 4),
        "offered_load_req_s": round(len(arrivals) / max(arrivals[-1], 1e-9),
                                    2),
        "high_priority": hi_m,
        "low_priority": lo_m,
        "admission_rejected": dict(rejected),
        "overload": {
            "shed_requests": st["shed_requests"],
            "preemptions": st["preemptions"],
            "preempt_resumes": st["preempt_resumes"],
            "breaker_trips": st["breaker_trips"],
            "timed_out": st["timed_out"],
            "dead_lettered": st["dead_lettered"],
            "peak_queue_depth": gauges["queue_depth"],
            "peak_queue_age_s": round(gauges["queue_age_s"], 4),
            "ewma_decode_s_per_tok": round(st["ewma_decode_s_per_tok"], 6),
        },
        "pump": {
            "pump_steps": st["pump_steps"],
            "pump_stall_notices": st["pump_stall_notices"],
        },
        "preempt_probe": {
            "victims": probe_preempted,
            "preempt_resumes_total": probe_stats["preempt_resumes"],
            "bit_identical": probe_identical,
        },
    }
    if args.replicas > 1:
        result["fleet"] = {
            "fleet_replicas": st["fleet_replicas"],
            "routed_requests": st["routed_requests"],
            "affinity_hits": st["affinity_hits"],
            "affinity_rate": st["affinity_rate"],
            "spilled_admissions": st["spilled_admissions"],
            "migrated_sessions": st["migrated_sessions"],
        }
    checks = {
        # the server stayed live: every submitted request reached a typed
        # terminal status, nothing stranded in a queue or slot
        "all_requests_terminal": (not inflight
                                  and all(s in terminal for s in statuses)),
        "nothing_live_after_drain": (probe_stats["queued_requests"] == 0
                                     and probe_stats["live_requests"] == 0),
        # overload control engaged and sheds carry typed errors
        "overload_exercised": overload_events > 0,
        "sheds_typed": sheds_typed,
        # interactive class protected while batch degrades
        "hi_p99_ttft_bounded": hi_m["ttft_p99_s"] <= args.hi_ttft_gate,
        # the directed probe preempted and resumed bit-identically
        "probe_preempted": probe_preempted >= 1,
        "preempt_resume_bit_identical": probe_identical,
    }
    if args.chaos:
        result["chaos"] = {
            "fault_rate": args.fault_rate,
            "faults_injected": sum(injector.injected.values()),
            "faults_by_site": dict(injector.injected),
            "dispatch_retries": st["dispatch_retries"],
            "dead_lettered": st["dead_lettered"],
        }
        checks["faults_injected_gt_0"] = sum(injector.injected.values()) > 0
    result["checks"] = checks
    server.close()

    write_artifact(args.out, result, seed=args.seed)
    print(json.dumps(result, indent=2))
    if not all(checks.values()):
        raise SystemExit("load_bench: robustness checks FAILED")
    print(f"load_bench: OK ({overload_events} overload events, hi p99 TTFT "
          f"{hi_m['ttft_p99_s']:.3f}s, probe bit-identical) -> {args.out}")


if __name__ == "__main__":
    main()
