"""Fig. 7a — MCP caching effect: Actor latency breakdown, N vs C.

Comparing N (no cache, no agent memory) against C (cache + S3 file handling,
no agent memory) isolates the MCP-level optimizations, per §5.3.1. Under
``--llm jax`` the C cells additionally exercise the cache × radix composition
(fame/toolflow.py): warm tool results re-enter the token stream as radix
prefix hits."""
from __future__ import annotations

import argparse
import os
import sys

try:
    from benchmarks import fame_common as fc
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import fame_common as fc


def main(matrix=None, argv=None):
    args = harness = None
    if argv is not None or matrix is None:
        ap = fc.add_common_args(argparse.ArgumentParser(description=__doc__),
                                default_out="results/fame_fig7a.json")
        args = ap.parse_args(argv if argv is not None else [])
        if args.llm == "jax":
            harness = fc.make_harness(args.arch)
    print("fig7a,app,input,query,config,actor_s,llm_s,mcp_s,cache_hits")
    reductions = []
    cells_by_app = {}
    for app in ("RS", "LA"):
        inp = {"RS": "P1", "LA": "L1"}[app]
        llm = args.llm if args is not None else "oracle"
        cells = {c: fc.run_cell(app, c, inp, llm=llm, harness=harness)
                 for c in ("N", "C")}
        cells_by_app[app] = cells
        for qi in range(3):
            for cname, cell in cells.items():
                sp = cell.agent_split_s[qi]
                print(f"fig7a,{app},{inp},Q{qi + 1},{cname},"
                      f"{sp['actor']:.2f},{sp['llm_s']:.2f},{sp['mcp_s']:.2f},"
                      f"{cells['C'].cache_hits if cname == 'C' else 0}")
            n_mcp = cells["N"].agent_split_s[qi]["mcp_s"]
            c_mcp = cells["C"].agent_split_s[qi]["mcp_s"]
            if qi > 0 and n_mcp > 0:          # warm-cache queries only
                reductions.append((n_mcp - c_mcp) / n_mcp)
    avg = sum(reductions) / len(reductions) if reductions else 0.0
    print(f"fig7a_derived,avg_warm_mcp_latency_reduction,{avg * 100:.0f}%")
    out = {"mcp_latency_reduction": avg}
    if args is not None:
        import dataclasses
        from _artifact import write_artifact
        write_artifact(args.out, dict(
            out, cells={f"{a}/{c}": dataclasses.asdict(cell)
                        for a, cells in cells_by_app.items()
                        for c, cell in cells.items()}))
    return out


if __name__ == "__main__":
    main(argv=sys.argv[1:])
