"""Fig. 7a — MCP caching effect: Actor latency breakdown, N vs C.

Comparing N (no cache, no agent memory) against C (cache + S3 file handling,
no agent memory) isolates the MCP-level optimizations, per §5.3.1."""
from __future__ import annotations

from benchmarks.fame_common import run_cell


def main(matrix=None):
    print("fig7a,app,input,query,config,actor_s,llm_s,mcp_s,cache_hits")
    reductions = []
    for app in ("RS", "LA"):
        inp = {"RS": "P1", "LA": "L1"}[app]
        cells = {c: run_cell(app, c, inp) for c in ("N", "C")}
        for qi in range(3):
            for cname, cell in cells.items():
                sp = cell.agent_split_s[qi]
                print(f"fig7a,{app},{inp},Q{qi + 1},{cname},"
                      f"{sp['actor']:.2f},{sp['llm_s']:.2f},{sp['mcp_s']:.2f},"
                      f"{cells['C'].cache_hits if cname == 'C' else 0}")
            n_mcp = cells["N"].agent_split_s[qi]["mcp_s"]
            c_mcp = cells["C"].agent_split_s[qi]["mcp_s"]
            if qi > 0 and n_mcp > 0:          # warm-cache queries only
                reductions.append((n_mcp - c_mcp) / n_mcp)
    avg = sum(reductions) / len(reductions) if reductions else 0.0
    print(f"fig7a_derived,avg_warm_mcp_latency_reduction,{avg * 100:.0f}%")
    return {"mcp_latency_reduction": avg}


if __name__ == "__main__":
    main()
