"""Serving fast-path benchmark: bucketed prefill + chunked decode A/B.

Runs the same mixed-length request stream through three engines sharing one
set of weights:

* **fast**   — bucketed prefill, chunked on-device decode (the fast path)
* **chunk1** — ablation: bucketed prefill but one engine step per token
* **seed**   — a frozen copy of the pre-fast-path engine (one jit compile per
  distinct prompt length, host-side tree-map cache splice on admission,
  host-side sampling, per-slot blocking ``int()`` pulls every token)

and reports decode throughput, prefill compile counts, host syncs per token,
and request-latency percentiles as JSON (the repo's BENCH trajectory):

    PYTHONPATH=src python benchmarks/serving_bench.py [--smoke] [--arch A]

Throughput is wall-clock based (drain wall minus prefill time, on a warmed
engine): the seed engine's own ``decode_s`` was measured before its blocking
host pulls and badly under-counts, so per-engine timers are not comparable.

Acceptance floor (ISSUE 1): fast decode tokens/sec >= 3x the seed engine on
CPU with num_slots=4 and mixed prompt lengths; prefill compiles <= number of
buckets; <= 1 host sync per decode chunk.
"""
from __future__ import annotations

import argparse
import json
import time

from _artifact import write_artifact

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# The seed engine, frozen for A/B (do not "fix" it — it is the baseline).
# ---------------------------------------------------------------------------


class SeedEngine:
    """Pre-fast-path serving loop: per-length prefill compiles, host-side
    cache splice, host-side sampling, one blocking pull per slot per token."""

    def __init__(self, cfg, *, num_slots=4, capacity=512, params=None, seed=0):
        from repro.models import Model
        from repro.serving.tokenizer import ByteTokenizer
        self.cfg = cfg
        self.model = Model(cfg)
        self.tokenizer = ByteTokenizer(cfg.vocab_size)
        self.num_slots = num_slots
        self.capacity = capacity
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.cache = self.model.init_cache(num_slots, capacity)
        self.slots = [None] * num_slots          # (req, generated, remaining)
        self.cache_lens = jnp.zeros((num_slots,), jnp.int32)
        self._rng = jax.random.PRNGKey(seed + 1)
        self._pending = []
        self._prefill_shapes = set()
        self._decode_syncs = 0
        self._decode_tokens = 0
        self._jit_decode = jax.jit(self._decode_step_fn)
        self._jit_prefill = jax.jit(self._prefill_fn)

    def _prefill_fn(self, params, tokens, positions):
        cache1 = self.model.init_cache(1, self.capacity)
        logits, cache1 = self.model.prefill(
            params, {"tokens": tokens, "positions": positions}, cache1)
        return logits[:, -1], cache1

    def _decode_step_fn(self, params, cache, tokens, positions, cache_len):
        logits, cache = self.model.decode_step(
            params, {"tokens": tokens, "positions": positions}, cache, cache_len)
        return logits[:, 0], cache

    def submit(self, prompt, *, max_new_tokens=64):
        req = {"prompt": prompt, "max_new": max_new_tokens, "prefill_s": 0.0,
               "out": [], "t0": time.perf_counter(), "latency_s": 0.0}
        self._pending.append(req)
        return req

    def _admit(self):
        from repro.serving.sampler import sample
        for si in range(self.num_slots):
            if self.slots[si] is not None or not self._pending:
                continue
            req = self._pending.pop(0)
            t0 = time.perf_counter()
            ids = self.tokenizer.encode(req["prompt"])[
                -(self.capacity - req["max_new"] - 1):]
            tokens = jnp.asarray([ids], jnp.int32)
            positions = jnp.arange(len(ids), dtype=jnp.int32)[None, :]
            self._prefill_shapes.add(len(ids))
            last_logits, cache1 = self._jit_prefill(self.params, tokens, positions)

            def _scan_leaf(full, one):
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype), (0, si) + (0,) * (full.ndim - 2))

            def _tail_leaf(full, one):
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype), (si,) + (0,) * (full.ndim - 1))

            self.cache = {
                k: jax.tree.map(_scan_leaf if k == "scan" else _tail_leaf,
                                self.cache[k], cache1[k])
                for k in self.cache}
            self.cache_lens = self.cache_lens.at[si].set(len(ids))
            self._rng, k = jax.random.split(self._rng)
            first = sample(last_logits, k, vocab_limit=self.cfg.vocab_size)
            self.slots[si] = (req, [int(first[0])], req["max_new"] - 1,
                              len(ids))
            req["prefill_s"] += time.perf_counter() - t0

    def step(self):
        from repro.serving.sampler import sample
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        last = [self.slots[i][1][-1] if self.slots[i] else 0
                for i in range(self.num_slots)]
        tokens = jnp.asarray(last, jnp.int32)[:, None]
        positions = self.cache_lens[:, None]
        logits, self.cache = self._jit_decode(self.params, self.cache, tokens,
                                              positions, self.cache_lens)
        self._rng, k = jax.random.split(self._rng)
        nxt = sample(logits, k, vocab_limit=self.cfg.vocab_size)
        self.cache_lens = self.cache_lens + jnp.asarray(
            [1 if s else 0 for s in self.slots], jnp.int32)
        for i in active:
            req, gen, rem, clen = self.slots[i]
            gen.append(int(nxt[i]))                  # blocking pull per slot
            self._decode_syncs += 1
            self._decode_tokens += 1
            rem -= 1
            clen += 1
            if (rem <= 0 or gen[-1] == self.tokenizer.eos_id
                    or clen >= self.capacity - 1):
                req["out"] = gen
                req["latency_s"] = time.perf_counter() - req["t0"]
                self.slots[i] = None
                self.cache_lens = self.cache_lens.at[i].set(0)
            else:
                self.slots[i] = (req, gen, rem, clen)
        return True

    def run_until_drained(self):
        while self.step() or self._pending:
            pass

    def stats(self):
        return {"prefill_compiles": len(self._prefill_shapes),
                "prefill_buckets": [],
                "decode_chunk": 1,
                "decode_chunks": self._decode_syncs,
                "decode_tokens": self._decode_tokens,
                "host_syncs": self._decode_syncs,
                "host_syncs_per_token": (self._decode_syncs
                                         / max(self._decode_tokens, 1))}


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------


def _percentile(xs, p):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, int(round(p / 100 * (len(xs) - 1))))
    return xs[i]


def make_prompts(n: int):
    """Mixed-length prompts: short / medium / long, interleaved."""
    base = [
        "ping",
        "summarize the introduction of the paper on FaaS-hosted agents",
        ("a much longer request: characterize the network and systems "
         "performance of MCP-enabled LLM agent workflows end to end, "
         "including tool-call fan-out, memory injection, and the serving "
         "engine's prefill and decode phases under continuous batching"),
    ]
    return [f"[{i}] {base[i % len(base)]}" for i in range(n)]


def run_engine(engine, prompts, max_new_tokens, *, is_seed=False):
    """Two passes: cold (counts compiles) then warm (throughput/latency)."""
    submit = (lambda p: engine.submit(p, max_new_tokens=max_new_tokens))
    reqs = [submit(p) for p in prompts]
    engine.run_until_drained()                     # cold pass: compiles
    cold = engine.stats()
    t0 = time.perf_counter()
    reqs = [submit(p) for p in prompts]
    engine.run_until_drained()
    wall = time.perf_counter() - t0
    if is_seed:
        prefill_s = sum(r["prefill_s"] for r in reqs)
        toks = sum(len(r["out"]) - 1 for r in reqs)
        lats = [r["latency_s"] for r in reqs]
    else:
        prefill_s = sum(r.prefill_s for r in reqs)
        toks = sum(r.output_tokens - 1 for r in reqs)
        lats = [r.latency_s for r in reqs]
    decode_wall = max(wall - prefill_s, 1e-9)
    warm = engine.stats()
    return {
        "warm_wall_s": round(wall, 4),
        "decode_wall_s": round(decode_wall, 4),
        "decode_tokens": toks,
        "decode_tok_s": round(toks / decode_wall, 2),
        "prefill_compiles": cold["prefill_compiles"],
        "prefill_buckets": cold["prefill_buckets"],
        "decode_chunk": warm["decode_chunk"],
        "decode_chunks": warm["decode_chunks"],
        "host_syncs": warm["host_syncs"],
        "host_syncs_per_token": round(warm["host_syncs_per_token"], 4),
        "p50_latency_s": round(_percentile(lats, 50), 4),
        "p95_latency_s": round(_percentile(lats, 95), 4),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=192)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--block-w", type=int, default=256)
    ap.add_argument("--out", default="results/serving_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI perf gating")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new = 6, 16

    from repro.configs.registry import ARCHS
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512)
    prompts = make_prompts(args.requests)

    fast = ServingEngine(
        cfg, num_slots=args.slots, capacity=args.capacity,
        engine_cfg=EngineConfig(decode_chunk=args.chunk, block_w=args.block_w))
    chunk1 = ServingEngine(
        cfg, num_slots=args.slots, capacity=args.capacity, params=fast.params,
        engine_cfg=EngineConfig(decode_chunk=1, block_w=args.block_w))
    seed = SeedEngine(cfg, num_slots=args.slots, capacity=fast.capacity,
                      params=fast.params)

    fast_r = run_engine(fast, prompts, args.max_new)
    chunk1_r = run_engine(chunk1, prompts, args.max_new)
    seed_r = run_engine(seed, prompts, args.max_new, is_seed=True)
    speedup = fast_r["decode_tok_s"] / max(seed_r["decode_tok_s"], 1e-9)

    result = {
        "bench": "serving_fast_path",
        "arch": args.arch,
        "num_slots": args.slots,
        "capacity": fast.capacity,
        "requests": args.requests,
        "max_new_tokens": args.max_new,
        "fast": fast_r,
        "chunk1_ablation": chunk1_r,
        "seed_baseline": seed_r,
        "decode_speedup_vs_seed": round(speedup, 2),
        "p50_speedup_vs_seed": round(
            seed_r["p50_latency_s"] / max(fast_r["p50_latency_s"], 1e-9), 2),
        "checks": {
            "decode_speedup_ge_3x": speedup >= 3.0,
            "prefill_compiles_le_buckets":
                fast_r["prefill_compiles"] <= len(fast_r["prefill_buckets"]),
            "le_one_sync_per_chunk":
                fast_r["host_syncs"] <= fast_r["decode_chunks"],
        },
    }
    write_artifact(args.out, result)
    print(json.dumps(result, indent=2))
    if not all(result["checks"].values()):
        raise SystemExit("serving_bench: perf checks FAILED")
    print(f"serving_bench: OK ({speedup:.1f}x decode throughput vs seed, "
          f"{fast_r['prefill_compiles']} prefill compiles, "
          f"{fast_r['host_syncs_per_token']:.3f} syncs/token) -> {args.out}")


if __name__ == "__main__":
    main()
