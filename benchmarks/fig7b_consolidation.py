"""Fig. 7b — Singleton vs consolidated MCP deployment under a 1-RPS synthetic
workload (§5.3.2): per-request total MCP latency timeline, cold starts, cost.

Mimics the paper's methodology: a Step-Function-like driver fires the
applications' MCP call sequence (each server invoked twice — two ReAct
iterations) at 1 RPS for 120 s, without spending agent LLM tokens."""
from __future__ import annotations

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.mcp import rpc_call
from repro.core.runtime import FameRuntime
from repro.core.telemetry import Trace, use_trace

SEQUENCES = {
    "RS": [("download_paper", {"title": rs.data.title_of("P1")}),
           ("summarize_text", {"query": "Summarize Introduction",
                               "text": "$inline"})] * 2,
    "LA": [("filter_by_keyword", {"file": "/logs/apache.log", "keyword": "AH01630"}),
           ("mean", {"values": "[1.0, 2.0, 3.0]"}),
           ("line_plot", {"data": "[1.0, 2.0, 3.0]", "title": "t"})] * 2,
}


def run_workload(app_key: str, fusion: str, *, rps: float = 1.0,
                 duration_s: float = 120.0):
    app = {"RS": rs, "LA": la}[app_key]
    rt = FameRuntime(config=CONFIGS["E"], fusion_mode=fusion)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)
    seq = SEQUENCES[app_key]
    points = []
    n = int(duration_s * rps)
    for i in range(n):
        t_arrival = i / rps
        trace = Trace()
        with use_trace(trace):
            t = t_arrival
            for tool, args in seq:
                fn = rt.resolve_tool_function(tool)
                if args.get("text") == "$inline":
                    args = dict(args, text=rs.data.paper_content("P1")[:2000])
                _, t = rt.platform.invoke(fn, {"body": rpc_call(tool, args)}, t)
        points.append((t_arrival, t - t_arrival))
    stats = rt.platform.stats
    cold = sum(s["cold_starts"] for k, s in stats.items() if k.startswith("mcp"))
    cost = sum(s["cost_cents"] for k, s in stats.items() if k.startswith("mcp"))
    calls = sum(s["invocations"] for k, s in stats.items() if k.startswith("mcp"))
    return points, cold, cost / max(calls, 1)


def main():
    print("fig7b,app,mode,t_arrival_s,total_mcp_latency_s")
    out = {}
    for app in ("RS", "LA"):
        for mode in ("singleton", "consolidated"):
            pts, cold, cents_per_call = run_workload(app, mode)
            for t, lat in pts[:10] + pts[30:40:3]:     # head + stable sample
                print(f"fig7b,{app},{mode},{t:.0f},{lat:.2f}")
            stable = [l for t, l in pts if t >= 40]
            avg_stable = sum(stable) / len(stable)
            print(f"fig7b_summary,{app},{mode},cold_starts={cold},"
                  f"stable_latency_s={avg_stable:.2f},"
                  f"cents_per_call={cents_per_call:.4f}")
            out[(app, mode)] = (cold, avg_stable, cents_per_call)
    for app in ("RS", "LA"):
        s, c = out[(app, "singleton")], out[(app, "consolidated")]
        print(f"fig7b_derived,{app},cold_start_reduction,{s[0]}->{c[0]},"
              f"stable_speedup,{s[1] / c[1]:.2f}x")
    return out


if __name__ == "__main__":
    main()
