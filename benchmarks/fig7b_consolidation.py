"""Fig. 7b — Singleton vs consolidated MCP deployment under a 1-RPS synthetic
workload (§5.3.2): per-request total MCP latency timeline, cold starts, cost.

Mimics the paper's methodology: a Step-Function-like driver fires the
applications' MCP call sequence (each server invoked twice — two ReAct
iterations) at 1 RPS for 120 s, without spending agent LLM tokens.

``--llm jax`` adds the serving-side consolidation story (fame/fusion.py):
three concurrent workflow chains run either serialized (singleton — each
agent invocation drains the engine alone) or co-batched (consolidated — all
invocations share engine steps via ``CoBatchDriver``), and the gate asserts
the consolidated run actually co-batches (``active_slots_per_step > 1``)."""
from __future__ import annotations

import argparse
import os
import sys
import time

try:
    from benchmarks import fame_common as fc
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import fame_common as fc

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.mcp import rpc_call
from repro.core.runtime import FameRuntime
from repro.core.telemetry import Trace, use_trace

SEQUENCES = {
    "RS": [("download_paper", {"title": rs.data.title_of("P1")}),
           ("summarize_text", {"query": "Summarize Introduction",
                               "text": "$inline"})] * 2,
    "LA": [("filter_by_keyword", {"file": "/logs/apache.log", "keyword": "AH01630"}),
           ("mean", {"values": "[1.0, 2.0, 3.0]"}),
           ("line_plot", {"data": "[1.0, 2.0, 3.0]", "title": "t"})] * 2,
}


def run_workload(app_key: str, fusion: str, *, rps: float = 1.0,
                 duration_s: float = 120.0):
    app = {"RS": rs, "LA": la}[app_key]
    rt = FameRuntime(config=CONFIGS["E"], fusion_mode=fusion)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)
    seq = SEQUENCES[app_key]
    points = []
    n = int(duration_s * rps)
    for i in range(n):
        t_arrival = i / rps
        trace = Trace()
        with use_trace(trace):
            t = t_arrival
            for tool, args in seq:
                fn = rt.resolve_tool_function(tool)
                if args.get("text") == "$inline":
                    args = dict(args, text=rs.data.paper_content("P1")[:2000])
                _, t = rt.platform.invoke(fn, {"body": rpc_call(tool, args)}, t)
        points.append((t_arrival, t - t_arrival))
    stats = rt.platform.stats
    cold = sum(s["cold_starts"] for k, s in stats.items() if k.startswith("mcp"))
    cost = sum(s["cost_cents"] for k, s in stats.items() if k.startswith("mcp"))
    calls = sum(s["invocations"] for k, s in stats.items() if k.startswith("mcp"))
    return points, cold, cost / max(calls, 1)


def run_serving_chains(arch: str, mode: str, smoke: bool) -> dict:
    """Three concurrent RS workflow chains (config M+C) on one real server:
    singleton serializes agent invocations, consolidated co-batches them."""
    harness = fc.make_harness(arch, cobatch=(mode == "consolidated"))
    app = rs

    def chain_thunk(inp):
        def run():
            rt, meter = fc._build_serving_runtime(app, "M+C", mode, harness)
            queries = app.APP.queries(inp)
            res = rt.run_session(f"RS-{inp}-{mode}",
                                 queries[:1] if smoke else queries)
            return res.statuses, meter
        return run

    before = dict(harness.server.stats())
    t0 = time.perf_counter()
    results = harness.driver.run([chain_thunk(i) for i in app.APP.inputs])
    makespan = time.perf_counter() - t0
    after = harness.server.stats()
    statuses = [s for st, _ in results for s in st]
    meters = [m for _, m in results]
    return {
        "mode": mode,
        "chains": len(results),
        "statuses": statuses,
        "makespan_s": makespan,
        "active_slots_per_step": after["active_slots_per_step"],
        "engine_steps": after["engine_steps"] - before["engine_steps"],
        "turns": sum(len(m.records) for m in meters),
        "all_terminal": all(m.all_terminal() for m in meters),
    }


def main(argv=None):
    args = None
    if argv is not None:
        ap = fc.add_common_args(argparse.ArgumentParser(description=__doc__),
                                default_out="results/fame_fig7b.json")
        args = ap.parse_args(argv)
    print("fig7b,app,mode,t_arrival_s,total_mcp_latency_s")
    out = {}
    for app in ("RS", "LA"):
        for mode in ("singleton", "consolidated"):
            pts, cold, cents_per_call = run_workload(app, mode)
            for t, lat in pts[:10] + pts[30:40:3]:     # head + stable sample
                print(f"fig7b,{app},{mode},{t:.0f},{lat:.2f}")
            stable = [l for t, l in pts if t >= 40]
            avg_stable = sum(stable) / len(stable)
            print(f"fig7b_summary,{app},{mode},cold_starts={cold},"
                  f"stable_latency_s={avg_stable:.2f},"
                  f"cents_per_call={cents_per_call:.4f}")
            out[(app, mode)] = (cold, avg_stable, cents_per_call)
    for app in ("RS", "LA"):
        s, c = out[(app, "singleton")], out[(app, "consolidated")]
        print(f"fig7b_derived,{app},cold_start_reduction,{s[0]}->{c[0]},"
              f"stable_speedup,{s[1] / c[1]:.2f}x")

    if args is not None and args.llm == "jax":
        from _artifact import write_artifact
        serving = {m: run_serving_chains(args.arch, m, args.smoke)
                   for m in ("singleton", "consolidated")}
        for m, r in serving.items():
            print(f"fig7b_serving,{m},chains={r['chains']},"
                  f"makespan_s={r['makespan_s']:.1f},"
                  f"active_slots_per_step={r['active_slots_per_step']:.2f},"
                  f"all_terminal={int(r['all_terminal'])}")
        failures = []
        cons = serving["consolidated"]
        if cons["active_slots_per_step"] <= 1.05:
            failures.append("consolidated chains did not co-batch "
                            f"(active_slots_per_step="
                            f"{cons['active_slots_per_step']:.2f})")
        if not all(s == "SUCCEEDED" for r in serving.values()
                   for s in r["statuses"]):
            failures.append("a serving chain DNF'd")
        if not all(r["all_terminal"] for r in serving.values()):
            failures.append("non-terminal handles after chain drain")
        write_artifact(args.out, {
            "oracle": {f"{a}/{m}": v for (a, m), v in out.items()},
            "serving": serving, "gate_failures": failures})
        for f in failures:
            print(f"GATE FAIL: {f}")
        print(f"fig7b_gates,{'FAIL' if failures else 'PASS'}")
        if failures:
            sys.exit(1)
    elif args is not None:
        from _artifact import write_artifact
        write_artifact(args.out,
                       {"oracle": {f"{a}/{m}": v for (a, m), v in out.items()}})
    return out


if __name__ == "__main__":
    main(argv=sys.argv[1:])
