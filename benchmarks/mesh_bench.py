"""Mesh-sharded serving A/B: single-device vs a 2×4 host mesh.

Runs the same greedy request stream through two servers sharing one set of
weights — the default single-device engine and one with
``EngineConfig(mesh=make_test_mesh((2, 4)))`` — and reports decode
throughput for both plus the **bit_identical** flag the CI ``mesh`` job
gates on (the outputs must match string-for-string; the serve layout never
splits a float contraction, see distributed/sharding.py).

On CPU the eight "devices" are XLA host threads carved from one socket, so
mesh throughput is a *correctness-under-partitioning* artifact, not a
speedup claim — the JSON records the ratio so regressions in partitioned
compile output are visible across PRs, and the same harness run on a real
8-chip slice measures true tensor-parallel scaling.

    PYTHONPATH=src python benchmarks/mesh_bench.py [--smoke] [--arch A]
                                                   [--mode dense|paged]

The script forces ``--xla_force_host_platform_device_count=8`` itself
(before importing jax) when the environment doesn't already provide enough
devices, so it runs identically under CI and bare invocation.

Exit status is non-zero when outputs diverge: the artifact is the evidence,
the exit code is the gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from _artifact import write_artifact

# must happen before `import jax` anywhere in this process
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax  # noqa: E402


def make_prompts(n):
    seeds = ["the quick brown fox jumps over the lazy dog",
             "err 429 err 429 err 429. retry with backoff. go",
             "a b c a b c a b c d e f",
             "summarize: the meeting moved to tuesday at noon"]
    return [seeds[i % len(seeds)] + f" [req {i}]" for i in range(n)]


def run_server(cfg, ecfg, prompts, max_new, *, slots, capacity, params=None):
    from repro.serving.server import LLMServer, SamplingParams
    srv = LLMServer(cfg, num_slots=slots, capacity=capacity, seed=7,
                    params=params, engine_cfg=ecfg)
    # warm the jits (prefill buckets + decode + extend) outside the timer
    w = srv.submit(prompts[0], SamplingParams(max_new_tokens=4))
    srv.run_until_idle()
    w.result()
    tok0 = srv.stats()["decode_tokens"]
    t0 = time.perf_counter()
    handles = [srv.submit(p, SamplingParams(max_new_tokens=max_new))
               for p in prompts]
    srv.run_until_idle()
    wall = time.perf_counter() - t0
    outs = [h.result() for h in handles]
    stats = srv.stats()
    toks = stats["decode_tokens"] - tok0
    params = srv.params
    srv.close()
    return {
        "wall_s": round(wall, 4),
        "decode_tokens": int(toks),
        "decode_tok_s": round(toks / max(wall, 1e-9), 2),
        "sharded": stats["sharded"],
        "mesh_devices": stats["mesh_devices"],
        "mesh_shape": stats["mesh_shape"],
    }, outs, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--mode", default="paged", choices=["dense", "paged"])
    ap.add_argument("--mesh-shape", default="2x4")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=96)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--spec-len", type=int, default=0)
    ap.add_argument("--out", default="results/mesh_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI gating")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new, args.slots = 6, 16, 2

    from repro.configs.registry import ARCHS
    from repro.launch.mesh import make_test_mesh
    from repro.serving.scheduler import EngineConfig

    shape = tuple(int(x) for x in args.mesh_shape.split("x"))
    mesh = make_test_mesh(shape)
    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512, num_kv_heads=4)
    prompts = make_prompts(args.requests)
    kw = dict(cache_mode=args.mode, page_size=8, spec_len=args.spec_len)

    single_r, single_out, params = run_server(
        cfg, EngineConfig(**kw), prompts, args.max_new,
        slots=args.slots, capacity=args.capacity)
    mesh_r, mesh_out, _ = run_server(
        cfg, EngineConfig(mesh=mesh, **kw), prompts, args.max_new,
        slots=args.slots, capacity=args.capacity,
        params=jax.device_get(params))

    bit_identical = single_out == mesh_out
    result = {
        "bench": "mesh_serving",
        "arch": args.arch,
        "cache_mode": args.mode,
        "mesh_shape": {"data": shape[0], "model": shape[1]},
        "device_count": jax.device_count(),
        "requests": args.requests,
        "max_new_tokens": args.max_new,
        "spec_len": args.spec_len,
        "single_device": single_r,
        "mesh": mesh_r,
        "mesh_over_single_tok_s": round(
            mesh_r["decode_tok_s"] / max(single_r["decode_tok_s"], 1e-9), 3),
        "bit_identical": bit_identical,
        "smoke": args.smoke,
    }
    write_artifact(args.out, result, seed=7)
    print(json.dumps(result, indent=2))
    if not bit_identical:
        print("FAIL: mesh output diverged from single-device", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
