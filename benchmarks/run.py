"""Benchmark runner — one function per paper table/figure.

Prints ``name,...`` CSV rows per figure plus derived headline numbers, and a
final validation block comparing against the paper's claims (13× latency,
88% input-token reduction, 66% cost reduction, DNF pattern).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig4_latency, fig5_tokens, fig6_cost,
                            fig7a_caching, fig7b_consolidation)
    from benchmarks.fame_common import run_matrix

    matrix = run_matrix()
    d4 = fig4_latency.main(matrix)
    d5 = fig5_tokens.main(matrix)
    d6 = fig6_cost.main(matrix)
    d7a = fig7a_caching.main()
    fig7b_consolidation.main()

    try:
        from benchmarks import roofline
        rows = roofline.analyze()
        print("roofline,arch,shape,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,mfu_bound_pct")
        for r in rows:
            print(f"roofline,{r['arch']},{r['shape']},{r['compute_s']:.4f},"
                  f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
                  f"{r['useful_ratio']:.3f},{r['mfu_bound'] * 100:.2f}")
    except FileNotFoundError:
        print("roofline,skipped (run repro.launch.dryrun --all first)")

    # ---- validation vs the paper's claims --------------------------------
    print("\n=== validation vs paper claims ===")
    checks = [
        ("latency speedup M+C vs baseline (paper: up to 13x)",
         d4["max_speedup"], 5.0),
        ("input-token reduction (paper: up to 88%)",
         d5["max_token_reduction"] * 100, 60.0),
        ("cost reduction (paper: up to 66%)",
         d6["max_cost_reduction"] * 100, 50.0),
        ("warm MCP latency reduction from caching (paper: ~28-33%)",
         d7a["mcp_latency_reduction"] * 100, 15.0),
    ]
    ok = True
    for name, value, floor in checks:
        status = "PASS" if value >= floor else "FAIL"
        ok &= value >= floor
        print(f"{status}: {name}: {value:.1f}")
    sys.exit(0 if ok else 1)


if __name__ == '__main__':
    main()
