"""Fig. 6 — Monetary cost decomposition: LLM vs agent-FaaS vs MCP-FaaS.

Under ``--llm jax`` the LLM component is priced from billed serving tokens
and the FaaS components meter real wall seconds charged into the simulated
clock (EXPERIMENTS.md §Billing)."""
from __future__ import annotations

import argparse
import os
import sys

try:
    from benchmarks import fame_common as fc
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import fame_common as fc


def main(matrix=None, argv=None):
    args = None
    if matrix is None:
        ap = fc.add_common_args(argparse.ArgumentParser(description=__doc__),
                                default_out="results/fame_fig6.json")
        args = ap.parse_args(argv if argv is not None else [])
        matrix, _ = fc.matrix_from_args(args)
    print("fig6,app,input,config,llm_cents,agent_faas_cents,mcp_faas_cents,"
          "total_cents,llm_share")
    totals = {}
    for (app, config, inp), cell in sorted(matrix.items()):
        llm = sum(cell.llm_cents)
        ag = sum(cell.faas_agent_cents)
        mcp = sum(cell.faas_mcp_cents)
        tot = llm + ag + mcp
        totals[(app, config, inp)] = tot
        share = llm / tot if tot else 0
        print(f"fig6,{app},{inp},{config},{llm:.3f},{ag:.3f},{mcp:.3f},"
              f"{tot:.3f},{share:.2f}")
    best = 0.0
    for app in ("RS", "LA"):
        for inp in {k[2] for k in totals if k[0] == app}:
            base = max(totals[(app, c, inp)] for c in ("E", "N"))
            ours = min(totals[(app, c, inp)] for c in ("C", "M", "M+C"))
            if base:
                best = max(best, (base - ours) / base)
    print(f"fig6_derived,max_cost_reduction,{best * 100:.0f}%")
    out = {"max_cost_reduction": best}
    if args is not None:
        from _artifact import write_artifact
        write_artifact(args.out, dict(out, matrix=fc.matrix_to_dict(matrix)))
    return out


if __name__ == "__main__":
    main(argv=sys.argv[1:])
