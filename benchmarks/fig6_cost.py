"""Fig. 6 — Monetary cost decomposition: LLM vs agent-FaaS vs MCP-FaaS."""
from __future__ import annotations

from benchmarks.fame_common import CONFIG_ORDER, run_matrix


def main(matrix=None):
    matrix = matrix or run_matrix()
    print("fig6,app,input,config,llm_cents,agent_faas_cents,mcp_faas_cents,"
          "total_cents,llm_share")
    totals = {}
    for (app, config, inp), cell in sorted(matrix.items()):
        llm = sum(cell.llm_cents)
        ag = sum(cell.faas_agent_cents)
        mcp = sum(cell.faas_mcp_cents)
        tot = llm + ag + mcp
        totals[(app, config, inp)] = tot
        share = llm / tot if tot else 0
        print(f"fig6,{app},{inp},{config},{llm:.3f},{ag:.3f},{mcp:.3f},"
              f"{tot:.3f},{share:.2f}")
    best = 0.0
    for app in ("RS", "LA"):
        for inp in {k[2] for k in totals if k[0] == app}:
            base = max(totals[(app, c, inp)] for c in ("E", "N"))
            ours = min(totals[(app, c, inp)] for c in ("C", "M", "M+C"))
            if base:
                best = max(best, (base - ours) / base)
    print(f"fig6_derived,max_cost_reduction,{best * 100:.0f}%")
    return {"max_cost_reduction": best}


if __name__ == "__main__":
    main()
