"""Prefix-sharing benchmark: radix prefix reuse vs the dense PR-1 cache.

Workload: the FAME multi-agent shape (PAPER.md §3.3) — N agents (Planner /
Actor / Evaluator) share one system prompt, and every turn's prompt is the
*whole conversation so far* plus a short new instruction, exactly the traffic
pattern whose re-sent prefix dominated input tokens in the paper. The same
request stream runs through two engines sharing one set of weights:

* **paged** — ``EngineConfig(cache_mode="paged")``: radix-matched prefixes
  are never re-prefilled. On full-attention archs the prefix's KV *pages*
  are reused outright; on stateful archs (``--arch recurrentgemma-9b`` /
  ``xlstm-350m`` / ``mixtral-8x22b``) the engine restores the nearest
  per-prefix recurrent-state *snapshot* and prefills only the suffix.
* **dense** — the PR-1 per-slot cache: every turn re-prefills its full
  prompt from scratch.

Reported: total prefill seconds (warm), prefill speedup, shared-prefix hit
rate (plus snapshot hit/capture counters on stateful archs), padding waste,
and an output-equality check (greedy decode must be identical between
modes):

    PYTHONPATH=src python benchmarks/prefix_bench.py [--smoke] [--arch A]

Acceptance floors (ISSUEs 2 and 4): paged prefill time <= 1/2 dense prefill
time on CPU with the multi-agent workload — for full-attention archs AND
for stateful archs via snapshots — identical greedy outputs, hit rate
reported in the JSON (CI runs ``--smoke`` for both as perf gates).
"""
from __future__ import annotations

import argparse
import json
import time

from _artifact import write_artifact


SYSTEM_PROMPT = (
    "System: You are one of several cooperating agents in a FaaS-hosted MCP "
    "workflow. Shared rules: keep tool calls minimal, cite evidence for "
    "every claim, prefer cached tool outputs when the arguments are "
    "identical, and hand off to the evaluator after each action. The "
    "conversation below is shared verbatim by every agent in this workflow "
    "session, so treat it as common ground. ")

AGENT_TURNS = [
    ("planner", "Plan: decompose the user goal into the next tool call."),
    ("actor", "Act: execute the planned tool call and record the output."),
    ("evaluator", "Evaluate: check the output against the goal; pass or retry."),
]


def make_workload(rounds: int):
    """Prompt stream: a growing conversation walked by 3 agents per round —
    every prompt is the long shared system prompt + the full history so far
    + a short per-turn instruction (the paper's re-sent-prefix shape). The
    bench's ``no_truncation`` check catches capacity/rounds mismatches (a
    truncated prompt would silently shrink the shareable prefix)."""
    history = ""
    prompts = []
    for r in range(rounds):
        for agent, turn in AGENT_TURNS:
            prompts.append(f"{SYSTEM_PROMPT}{history}[{agent}] {turn}")
        history += f"(round {r}: plan->act->eval ok) "
    return prompts


def run_engine(engine, prompts, max_new):
    """Two cold passes, then a warm measured pass. Two because the paged
    engine's steady state differs from its first pass: once the radix tree
    holds the conversation, suffix chunks take different (smaller) bucket
    shapes, and those compiles must not land in the measured pass."""
    for _ in range(2):
        for p in prompts:
            engine.submit(p, max_new_tokens=max_new)
        engine.run_until_drained()
    cold = engine.stats()
    t0 = time.perf_counter()
    reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    engine.run_until_drained()
    wall = time.perf_counter() - t0
    warm = engine.stats()
    # engine counters are lifetime totals; report the measured pass only
    # (the warm-up passes' compulsory misses and padding would otherwise
    # skew the steady-state numbers README tells users to tune from)
    d = lambda k: warm.get(k, 0) - cold.get(k, 0)
    prefill_s = sum(r.prefill_s for r in reqs)
    computed = max(d("prefill_pad_tokens") + d("prompt_tokens")
                   - d("prefix_hit_tokens"), 1)
    return {
        "warm_wall_s": round(wall, 4),
        "prefill_s": round(prefill_s, 4),
        "decode_wall_s": round(max(wall - prefill_s, 1e-9), 4),
        "prefill_compiles": cold["prefill_compiles"],
        "extend_compiles": cold["extend_compiles"],
        # compiles landing in the measured pass would silently absorb compile
        # time into prefill_s — surface them (0 in a healthy run)
        "measured_pass_compiles": (d("prefill_compiles")
                                   + d("extend_compiles")),
        "prefill_pad_tokens": d("prefill_pad_tokens"),
        "prefill_pad_frac": round(d("prefill_pad_tokens") / computed, 4),
        "prompt_tokens": d("prompt_tokens"),
        "truncated_tokens": d("truncated_tokens"),
        "prefix_hit_tokens": d("prefix_hit_tokens"),
        "prefix_hit_rate": round(d("prefix_hit_tokens")
                                 / max(d("prompt_tokens"), 1), 4),
        "pages_peak_in_use": warm.get("pages_peak_in_use", 0),
        "radix_evicted_pages": warm.get("radix_evicted_pages", 0),
        # snapshot mode (stateful archs): restored vs from-scratch admissions
        "snapshot_hits": d("snapshot_hits"),
        "snapshot_misses": d("snapshot_misses"),
        "snapshot_captures": d("snapshot_captures"),
        "snapshots_peak_in_use": warm.get("snapshots_peak_in_use", 0),
        "snapshot_evictions": warm.get("snapshot_evictions", 0),
    }, [r.output_text for r in reqs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--rounds", type=int, default=4,
                    help="conversation rounds (3 agent turns each)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--out", default="results/prefix_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI perf gating")
    args = ap.parse_args()
    if args.smoke:
        args.rounds, args.capacity = 3, 448

    from repro.configs.registry import ARCHS
    from repro.serving.engine import EngineConfig, ServingEngine

    # a notch bigger than the test-suite smoke dims: prefill must be
    # compute-bound (not jit-dispatch-bound) for the A/B to measure the
    # algorithmic win rather than per-call overhead
    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512, d_model=256, num_heads=8,
                                   head_dim=32, d_ff=512, num_layers=4)
    prompts = make_workload(args.rounds)

    paged = ServingEngine(
        cfg, num_slots=args.slots, capacity=args.capacity,
        engine_cfg=EngineConfig(decode_chunk=args.chunk, cache_mode="paged",
                                page_size=args.page_size))
    dense = ServingEngine(
        cfg, num_slots=args.slots, capacity=args.capacity, params=paged.params,
        engine_cfg=EngineConfig(decode_chunk=args.chunk))

    paged_r, paged_out = run_engine(paged, prompts, args.max_new)
    dense_r, dense_out = run_engine(dense, prompts, args.max_new)
    speedup = dense_r["prefill_s"] / max(paged_r["prefill_s"], 1e-9)

    result = {
        "bench": "prefix_sharing",
        "arch": args.arch,
        "num_slots": args.slots,
        "capacity": paged.capacity,
        "page_size": args.page_size,
        "requests": len(prompts),
        "max_new_tokens": args.max_new,
        "paged": paged_r,
        "dense_baseline": dense_r,
        "prefill_speedup_vs_dense": round(speedup, 2),
        "checks": {
            "prefill_speedup_ge_2x": speedup >= 2.0,
            "outputs_bit_identical": paged_out == dense_out,
            "prefix_hit_rate_reported": paged_r["prefix_hit_rate"] > 0.0,
            "no_truncation": paged_r["truncated_tokens"] == 0,
        },
    }
    write_artifact(args.out, result)
    print(json.dumps(result, indent=2))
    if not all(result["checks"].values()):
        raise SystemExit("prefix_bench: perf checks FAILED")
    print(f"prefix_bench: OK ({speedup:.1f}x prefill vs dense, "
          f"{paged_r['prefix_hit_rate']:.0%} prefix hit rate, "
          f"outputs identical) -> {args.out}")


if __name__ == "__main__":
    main()
