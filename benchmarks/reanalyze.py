"""Re-derive cost summaries from the dry-run's saved HLO artifacts
(results/hlo/*.hlo.zst) without recompiling — parser iterations are free."""
from __future__ import annotations

import json
import os
import sys

import zstandard

from repro.launch import hlo_cost


def reanalyze(json_path: str, suffix: str):
    data = json.load(open(json_path))
    for r in data["results"]:
        tag = f"{r['arch']}_{r['shape']}_{suffix}"
        path = f"results/hlo/{tag}.hlo.zst"
        if not os.path.exists(path):
            print(f"  missing {path}; keeping stored numbers")
            continue
        hlo = zstandard.ZstdDecompressor().decompress(
            open(path, "rb").read()).decode()
        cost = hlo_cost.analyze(hlo)
        r["flops"] = cost.flops
        r["bytes_accessed"] = cost.bytes_accessed
        r["bytes_min"] = cost.bytes_min
        r["collectives"] = {"total_bytes": cost.collective_bytes,
                            "bytes": cost.collective_bytes_by_op,
                            "counts": cost.collective_counts}
    json.dump(data, open(json_path, "w"), indent=1)
    print(f"reanalyzed {len(data['results'])} cells -> {json_path}")


if __name__ == "__main__":
    reanalyze("results/dryrun_single_pod.json", "sp")
    reanalyze("results/dryrun_multi_pod.json", "mp")
