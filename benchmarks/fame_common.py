"""Shared harness for the FAME paper-figure benchmarks (Figs. 4–7).

Runs both applications × all five Table-1 configs × all three inputs and
aggregates the traces, on either backend:

* ``llm="oracle"`` — the seed's simulated-clock path (``core/runtime``):
  deterministic, no jax needed.
* ``llm="jax"`` — the real serving stack (``fame/``): every agent turn and
  tool injection is a request on one warm ``LLMServer`` with tiny untrained
  configs; decisions stay oracle-guided so statuses are identical across
  backends (determinism note in EXPERIMENTS.md). Each cell gets a fresh
  ``ServingMeter`` plus a server-stats delta, so the per-cell serving story
  (tail reuse, cache × radix hits, fault taxonomy) survives sharing one
  warm server across the matrix.

Everything is deterministic (the paper averages three runs of a stochastic
LLM; our decisions are exact, so one run per cell — noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List, Optional

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime

APPS = {"RS": rs, "LA": la}
CONFIG_ORDER = ["E", "N", "C", "M", "M+C"]
MEMORY_CONFIGS = ("M", "M+C")      # persistent-session (tail-reuse) configs
CACHING_CONFIGS = ("C", "M+C")     # toolflow-injection configs


@dataclasses.dataclass
class CellResult:
    app: str
    config: str
    inp: str
    statuses: List[str]
    e2e_s: List[float]                 # per query
    agent_split_s: List[Dict[str, float]]
    in_tokens: List[int]
    out_tokens: List[int]
    llm_cents: List[float]
    faas_agent_cents: List[float]
    faas_mcp_cents: List[float]
    tool_calls: List[int]
    cache_hits: int
    serving: Optional[dict] = None     # jax backend only: meter summary,
                                       # per-request records, stats delta,
                                       # gate booleans

    @property
    def dnf(self):
        return [s != "SUCCEEDED" for s in self.statuses]


# ---------------------------------------------------------------------------
# Real-server harness (shared warm LLMServer across the matrix)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JaxHarness:
    server: object
    driver: object
    injector: object
    arch: str
    page_size: int
    max_new_tokens: int
    cobatch: bool


def make_harness(arch: str = "qwen2.5-3b", *, max_new_tokens: int = 8,
                 capacity: int = 2048, num_slots: int = 4,
                 page_size: int = 16, cobatch: bool = False,
                 seed: int = 0) -> JaxHarness:
    """One warm server for every cell: tiny float32 config, paged KV (radix
    sharing on), an armable-but-inert FaultInjector, and a warmup turn so
    the smallest prefill/decode programs compile before timing starts."""
    from repro.configs.registry import ARCHS
    from repro.serving.faults import FaultInjector
    from repro.serving.scheduler import EngineConfig, SamplingParams
    from repro.serving.server import LLMServer
    from repro.fame.fusion import CoBatchDriver, SerialDriver

    cfg = ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                              vocab_size=512)
    injector = FaultInjector(seed=seed)
    server = LLMServer(cfg, num_slots=num_slots, capacity=capacity,
                       engine_cfg=EngineConfig(cache_mode="paged",
                                               page_size=page_size,
                                               decode_chunk=8),
                       injector=injector, seed=seed)
    h = server.submit("warmup " * 8,
                      SamplingParams(max_new_tokens=max_new_tokens))
    server.run_until_idle()
    assert h.request.finished
    driver = CoBatchDriver(server) if cobatch else SerialDriver(server)
    return JaxHarness(server=server, driver=driver, injector=injector,
                      arch=arch, page_size=page_size,
                      max_new_tokens=max_new_tokens, cobatch=cobatch)


def _build_serving_runtime(app, config: str, fusion: str,
                           harness: JaxHarness, **rt_kwargs):
    from repro.fame import ServingMeter, WorkflowServingRuntime
    from repro.serving.scheduler import SamplingParams
    meter = ServingMeter(harness.server)
    rt = WorkflowServingRuntime(
        config=CONFIGS[config], server=harness.server,
        driver=harness.driver, meter=meter,
        params=SamplingParams(max_new_tokens=harness.max_new_tokens),
        fusion_mode=fusion, **rt_kwargs)
    for role, o in app.build_oracles().items():
        rt.set_llm(role, o)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)
    return rt, meter


def run_cell(app_key: str, config: str, inp: str,
             fusion: str = "singleton", llm: str = "oracle",
             harness: Optional[JaxHarness] = None) -> CellResult:
    app = APPS[app_key]
    serving = None
    if llm == "jax":
        if harness is None:
            harness = make_harness()
        rt, meter = _build_serving_runtime(app, config, fusion, harness)
        before = meter.snapshot()
        res = rt.run_session(f"{app_key}-{inp}", app.APP.queries(inp))
        after = meter.snapshot()
        serving = {
            "meter": meter.summary(),
            "stats_delta": meter.delta(before, after),
            "records": [dataclasses.asdict(r) for r in meter.records],
            "tail_reuse_ok": meter.tail_reuse_ok(),
            "injection_radix_ok": meter.injection_radix_ok(
                harness.page_size),
            "all_terminal": (meter.all_terminal()
                             and after.get("queued_requests", 0) == 0
                             and after.get("live_requests", 0) == 0),
        }
    else:
        rt = FameRuntime(config=CONFIGS[config], fusion_mode=fusion)
        for role, o in app.build_oracles().items():
            rt.set_llm(role, o)
        rt.deploy_mcp(app.APP.servers, app.APP.sources)
        res = rt.run_session(f"{app_key}-{inp}", app.APP.queries(inp))
    e2e, splits, itoks, otoks, llmc, agc, mcpc, calls = [], [], [], [], [], [], [], []
    for tr in res.traces:
        faas = [s for s in tr.spans if s.kind == "faas"]
        e2e.append(max((s.t_end for s in faas), default=0)
                   - min((s.t_start for s in faas), default=0))
        split = {}
        for agent in ("planner", "actor", "evaluator"):
            split[agent] = sum(s.duration for s in faas
                               if s.name == f"fame-{agent}")
        split["llm_s"] = tr.duration_of("llm")
        split["mcp_s"] = tr.duration_of("mcp")
        splits.append(split)
        i, o = tr.llm_tokens()
        itoks.append(i)
        otoks.append(o)
        cb = tr.cost_breakdown()
        llmc.append(cb["llm_cents"])
        agc.append(cb["faas_agent_cents"])
        mcpc.append(cb["faas_mcp_cents"])
        calls.append(sum(1 for s in tr.spans if s.kind == "mcp"
                         and s.attrs.get("method") == "tools/call"
                         or (s.kind == "mcp" and s.attrs.get("cache_hit"))))
    return CellResult(app_key, config, inp, res.statuses, e2e, splits,
                      itoks, otoks, llmc, agc, mcpc, calls, rt.cache.hits,
                      serving)


def run_matrix(fusion: str = "singleton", llm: str = "oracle",
               smoke: bool = False,
               harness: Optional[JaxHarness] = None):
    if llm == "jax" and harness is None:
        harness = make_harness()
    out = {}
    for app_key, app in APPS.items():
        inputs = app.APP.inputs[:1] if smoke else app.APP.inputs
        for config in CONFIG_ORDER:
            for inp in inputs:
                out[(app_key, config, inp)] = run_cell(
                    app_key, config, inp, fusion=fusion, llm=llm,
                    harness=harness)
    return out


# ---------------------------------------------------------------------------
# CLI plumbing shared by the fig benchmarks
# ---------------------------------------------------------------------------

def add_common_args(ap: argparse.ArgumentParser, default_out: str):
    ap.add_argument("--llm", choices=["oracle", "jax"], default="oracle",
                    help="oracle = simulated-clock seed path; jax = real "
                         "LLMServer inference (EXPERIMENTS.md)")
    ap.add_argument("--smoke", action="store_true",
                    help="one input per app instead of three (CI gate)")
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--fusion", choices=["singleton", "consolidated"],
                    default="singleton")
    ap.add_argument("--out", default=default_out)
    return ap


def matrix_from_args(args):
    harness = None
    if args.llm == "jax":
        harness = make_harness(args.arch)
    matrix = run_matrix(fusion=args.fusion, llm=args.llm,
                        smoke=args.smoke, harness=harness)
    return matrix, harness


def matrix_to_dict(matrix) -> dict:
    return {f"{k[0]}/{k[1]}/{k[2]}": dataclasses.asdict(v)
            for k, v in matrix.items()}


# ---------------------------------------------------------------------------
# CI gates (fig4_latency --smoke --llm jax)
# ---------------------------------------------------------------------------

def check_jax_gates(matrix, harness: JaxHarness) -> List[str]:
    """The acceptance invariants for the real-inference matrix; returns a
    list of human-readable failures (empty = pass)."""
    failures = []
    apps = sorted({k[0] for k in matrix})

    def cells(app, config):
        return [v for k, v in matrix.items()
                if k[0] == app and k[1] == config]

    for app in apps:
        e_lat = sum(sum(c.e2e_s) for c in cells(app, "E"))
        mc_lat = sum(sum(c.e2e_s) for c in cells(app, "M+C"))
        if not mc_lat < e_lat:
            failures.append(f"{app}: M+C e2e latency {mc_lat:.1f}s not "
                            f"below baseline E {e_lat:.1f}s")
        e_tok = sum(sum(c.in_tokens) for c in cells(app, "E"))
        mc_tok = sum(sum(c.in_tokens) for c in cells(app, "M+C"))
        if not mc_tok < e_tok:
            failures.append(f"{app}: M+C input tokens {mc_tok} not below "
                            f"baseline E {e_tok}")

    for app in apps:
        for config in MEMORY_CONFIGS:
            for c in cells(app, config):
                m = c.serving["meter"]
                if m["continuation_turns"] == 0:
                    failures.append(f"{app}/{config}/{c.inp}: no session "
                                    "tail continuations recorded")
                if not c.serving["tail_reuse_ok"]:
                    failures.append(f"{app}/{config}/{c.inp}: a continuation "
                                    "turn re-prefilled its history")
                if c.serving["stats_delta"].get("turn_prefix_hits", 0) <= 0:
                    failures.append(f"{app}/{config}/{c.inp}: server stats "
                                    "show no turn_prefix_hits")

    hit_injections = 0
    for app in apps:
        for config in CACHING_CONFIGS:
            for c in cells(app, config):
                hit_injections += c.serving["meter"]["cache_hit_injections"]
                if not c.serving["injection_radix_ok"]:
                    failures.append(f"{app}/{config}/{c.inp}: a cache-hit "
                                    "injection re-prefilled instead of "
                                    "radix-hitting")
    if hit_injections == 0:
        failures.append("no cache-hit tool injections anywhere in the "
                        "caching configs — cache × radix composition "
                        "untested")

    for k, c in matrix.items():
        if c.serving is not None and not c.serving["all_terminal"]:
            failures.append(f"{'/'.join(k)}: non-terminal handles or "
                            "stranded engine work")
    return failures


def check_fault_path(harness: JaxHarness, app_key: str = "LA") -> dict:
    """Per-state Retry over the PR-6 taxonomy, on the real server.

    Scenario 1 — injected fault: arm the injector to fail the next decode
    dispatch with ``RequestFault`` (decode always runs; a warm radix cache
    can route admission around the bucketed-prefill site); the planner turn
    dies FAILED, the state machine's Retry re-runs the state, the workflow
    still SUCCEEDs.
    Scenario 2 — deadline: a microscopic per-turn ``deadline_s`` times every
    turn out; retries exhaust; the workflow dead-letters into FailState.
    """
    from repro.core.workflow import Retry
    from repro.serving.faults import RequestFault
    app = APPS[app_key]
    report: dict = {}

    harness.injector.fail_next("decode", n=1,
                               exc=RequestFault, msg="injected chaos")
    rt, meter = _build_serving_runtime(
        app, "M+C", "singleton", harness,
        state_retry=Retry(max_attempts=2, backoff_s=0.1))
    res = rt.run_session(f"{app_key}-fault", app.APP.queries(
        app.APP.inputs[0])[:1])
    report["fault_retry_statuses"] = res.statuses
    report["fault_error_types"] = sorted(
        {r.error_type for r in meter.records if r.error_type})
    report["fault_all_terminal"] = meter.all_terminal()

    rt, meter = _build_serving_runtime(
        app, "M+C", "singleton", harness,
        state_retry=Retry(max_attempts=2, backoff_s=0.01),
        state_deadline_s=1e-4)
    res = rt.run_session(f"{app_key}-deadline", app.APP.queries(
        app.APP.inputs[0])[:1])
    report["deadline_statuses"] = res.statuses
    report["deadline_error_types"] = sorted(
        {r.error_type for r in meter.records if r.error_type})
    report["deadline_all_terminal"] = meter.all_terminal()

    report["ok"] = (report["fault_retry_statuses"] == ["SUCCEEDED"]
                    and "RequestFault" in report["fault_error_types"]
                    and report["fault_all_terminal"]
                    and all(s == "FAILED"
                            for s in report["deadline_statuses"])
                    and report["deadline_error_types"]
                        == ["DeadlineExceeded"]
                    and report["deadline_all_terminal"])
    return report
