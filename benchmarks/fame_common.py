"""Shared harness for the FAME paper-figure benchmarks (Figs. 4–7).

Runs both applications × all five Table-1 configs × all three inputs and
aggregates the traces. Everything is deterministic (the paper averages three
runs of a stochastic LLM; our oracle is exact, so one run per cell — noted in
EXPERIMENTS.md)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime

APPS = {"RS": rs, "LA": la}
CONFIG_ORDER = ["E", "N", "C", "M", "M+C"]


@dataclasses.dataclass
class CellResult:
    app: str
    config: str
    inp: str
    statuses: List[str]
    e2e_s: List[float]                 # per query
    agent_split_s: List[Dict[str, float]]
    in_tokens: List[int]
    out_tokens: List[int]
    llm_cents: List[float]
    faas_agent_cents: List[float]
    faas_mcp_cents: List[float]
    tool_calls: List[int]
    cache_hits: int

    @property
    def dnf(self):
        return [s != "SUCCEEDED" for s in self.statuses]


def run_cell(app_key: str, config: str, inp: str,
             fusion: str = "singleton") -> CellResult:
    app = APPS[app_key]
    rt = FameRuntime(config=CONFIGS[config], fusion_mode=fusion)
    for role, o in app.build_oracles().items():
        rt.set_llm(role, o)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)
    res = rt.run_session(f"{app_key}-{inp}", app.APP.queries(inp))
    e2e, splits, itoks, otoks, llmc, agc, mcpc, calls = [], [], [], [], [], [], [], []
    for tr in res.traces:
        faas = [s for s in tr.spans if s.kind == "faas"]
        e2e.append(max((s.t_end for s in faas), default=0)
                   - min((s.t_start for s in faas), default=0))
        split = {}
        for agent in ("planner", "actor", "evaluator"):
            split[agent] = sum(s.duration for s in faas
                               if s.name == f"fame-{agent}")
        split["llm_s"] = tr.duration_of("llm")
        split["mcp_s"] = tr.duration_of("mcp")
        splits.append(split)
        i, o = tr.llm_tokens()
        itoks.append(i)
        otoks.append(o)
        cb = tr.cost_breakdown()
        llmc.append(cb["llm_cents"])
        agc.append(cb["faas_agent_cents"])
        mcpc.append(cb["faas_mcp_cents"])
        calls.append(sum(1 for s in tr.spans if s.kind == "mcp"
                         and s.attrs.get("method") == "tools/call"
                         or (s.kind == "mcp" and s.attrs.get("cache_hit"))))
    return CellResult(app_key, config, inp, res.statuses, e2e, splits,
                      itoks, otoks, llmc, agc, mcpc, calls, rt.cache.hits)


def run_matrix(fusion: str = "singleton"):
    out = {}
    for app_key, app in APPS.items():
        for config in CONFIG_ORDER:
            for inp in app.APP.inputs:
                out[(app_key, config, inp)] = run_cell(app_key, config, inp,
                                                       fusion=fusion)
    return out
