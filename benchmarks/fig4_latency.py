"""Fig. 4 — End-to-end workflow execution latencies per (app, input, query,
config), with per-agent splits, tool-call counts and DNF tags."""
from __future__ import annotations

from benchmarks.fame_common import CONFIG_ORDER, run_matrix


def main(matrix=None):
    matrix = matrix or run_matrix()
    print("fig4,app,input,query,config,e2e_s,planner_s,actor_s,evaluator_s,"
          "tool_calls,dnf")
    derived = {}
    for (app, config, inp), cell in sorted(matrix.items()):
        for qi in range(3):
            sp = cell.agent_split_s[qi]
            print(f"fig4,{app},{inp},Q{qi + 1},{config},"
                  f"{cell.e2e_s[qi]:.1f},{sp['planner']:.1f},{sp['actor']:.1f},"
                  f"{sp['evaluator']:.1f},{cell.tool_calls[qi]},"
                  f"{int(cell.dnf[qi])}")
    # headline: max speedup of M+C vs worst baseline on completed queries
    best = 0.0
    for (app, config, inp), cell in matrix.items():
        if config != "M+C":
            continue
        for qi in range(3):
            for base in ("E", "N"):
                b = matrix[(app, base, inp)]
                if not b.dnf[qi] and cell.e2e_s[qi] > 0:
                    best = max(best, b.e2e_s[qi] / cell.e2e_s[qi])
    print(f"fig4_derived,max_speedup_MC_vs_baseline,{best:.1f}x")
    return {"max_speedup": best}


if __name__ == "__main__":
    main()
