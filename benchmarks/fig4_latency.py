"""Fig. 4 — End-to-end workflow execution latencies per (app, input, query,
config), with per-agent splits, tool-call counts and DNF tags.

``--llm jax`` runs the matrix on the real ``LLMServer`` (fame/ subsystem) and
asserts the serving invariants — M+C beats baseline E on latency and input
tokens, memory configs reuse session tails instead of re-prefilling history,
cache-hit tool injections radix-hit, and per-state retries route through the
PR-6 fault taxonomy with every handle terminal. This is the CI smoke gate
(``--smoke --llm jax``)."""
from __future__ import annotations

import argparse
import os
import sys

try:
    from benchmarks import fame_common as fc
except ModuleNotFoundError:                      # `python benchmarks/fig4_latency.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import fame_common as fc


def main(matrix=None, argv=None):
    args = harness = None
    if matrix is None:
        ap = fc.add_common_args(argparse.ArgumentParser(description=__doc__),
                                default_out="results/fame_fig4.json")
        args = ap.parse_args(argv if argv is not None else [])
        matrix, harness = fc.matrix_from_args(args)
    print("fig4,app,input,query,config,e2e_s,planner_s,actor_s,evaluator_s,"
          "tool_calls,dnf")
    for (app, config, inp), cell in sorted(matrix.items()):
        for qi in range(3):
            sp = cell.agent_split_s[qi]
            print(f"fig4,{app},{inp},Q{qi + 1},{config},"
                  f"{cell.e2e_s[qi]:.1f},{sp['planner']:.1f},{sp['actor']:.1f},"
                  f"{sp['evaluator']:.1f},{cell.tool_calls[qi]},"
                  f"{int(cell.dnf[qi])}")
    # headline: max speedup of M+C vs worst baseline on completed queries
    best = 0.0
    for (app, config, inp), cell in matrix.items():
        if config != "M+C":
            continue
        for qi in range(3):
            for base in ("E", "N"):
                b = matrix[(app, base, inp)]
                if not b.dnf[qi] and cell.e2e_s[qi] > 0:
                    best = max(best, b.e2e_s[qi] / cell.e2e_s[qi])
    print(f"fig4_derived,max_speedup_MC_vs_baseline,{best:.1f}x")
    out = {"max_speedup": best}

    if args is not None and args.llm == "jax":
        from _artifact import write_artifact
        failures = fc.check_jax_gates(matrix, harness)
        fault_report = fc.check_fault_path(harness)
        if not fault_report["ok"]:
            failures.append(f"fault-path check failed: {fault_report}")
        out.update(fault_report=fault_report, gate_failures=failures,
                   server_stats=harness.server.stats())
        write_artifact(args.out, dict(out, matrix=fc.matrix_to_dict(matrix)))
        for f in failures:
            print(f"GATE FAIL: {f}")
        print(f"fig4_gates,{'FAIL' if failures else 'PASS'},"
              f"fault_path={'PASS' if fault_report['ok'] else 'FAIL'}")
        if failures:
            sys.exit(1)
    elif args is not None:
        from _artifact import write_artifact
        write_artifact(args.out, dict(out, matrix=fc.matrix_to_dict(matrix)))
    return out


if __name__ == "__main__":
    main(argv=sys.argv[1:])
