"""Replica-fleet benchmark: 1 vs N engines under bursty open-loop load.

Drives the same trace (load_bench's generator) against a single-replica
``FleetServer`` and an N-replica fleet with **identical per-replica
resources** (slots, admission queue depth, decode chunk), and reports the
capacity the fleet adds:

* **goodput** — completed-within-SLO requests (and their tokens) per wall
  second. Under the built-in bursty trace each clump oversubscribes a
  single replica's bounded admission queue, so the single-replica phase
  sheds a large fraction while the fleet absorbs the burst across N
  queues (plus fleet-level spill before any replica's shed path engages).
  The smoke gate requires fleet goodput >= ``--goodput-gate`` (1.6x) the
  single-replica phase at N=2.
* **prefix affinity** — arrivals are drawn from a small set of prompt
  groups sharing a >= page-size token prefix (the multi-tenant "same
  system prompt" shape). The router lands repeat groups on the replica
  whose radix keyspace already holds their first block; reported as
  ``affinity_hits`` / ``affinity_rate`` alongside each replica's
  ``prefix_hit_tokens``.
* **per-step service floor** — ``--step-delay-ms`` wedges every replica's
  engine loop with a fixed sleep. The reduced CPU model decodes so fast
  that bursts would drain before admission control engages; the floor
  makes per-request service time deterministic and host-speed-independent,
  so the 1-vs-N comparison measures *placement and admission capacity*,
  not the CI box's flops. Both phases get the same floor.
* **crash-migration probe** — a fresh 2-replica fleet pins K sessions to
  one replica (same first turn => prefix affinity co-pins them), completes
  turn 1, kills that replica's pump (chaos-style ``_step_impl`` raiser),
  then submits turn 2: the fleet must journal-replay every session onto
  the healthy peer and the continuations must be **bit-identical** to an
  uninterrupted single-server reference run with the same weights.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--smoke] [--replicas N]

Acceptance gates (ISSUE 10, CI runs ``--smoke``): fleet goodput >= 1.6x
single-replica at N=2 under the bursty trace, affinity hits > 0 (rate
reported), every submitted request reaches a terminal status in both
phases, and the crash-migration probe's turn-2 outputs are token-identical
with ``migrated_sessions`` covering every pinned session.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

from _artifact import write_artifact
from load_bench import make_arrivals, pctl

# prompt groups sharing a >= page-size token prefix (distinct from the
# first character so ungrouped traffic would spread by load instead);
# kept short so prefill is a single engine step and the --step-delay-ms
# floor, not prefill compute, sets the service time
GROUPS = [f"{g} tenant {g}: " for g in range(6)]
T1 = "user: summarize the incident report assistant:"
DELTA = " user: and what is the root cause? assistant:"


def _slow_steps(server, delay_s: float):
    """Deterministic per-step service-time floor (see module docstring)."""
    if delay_s <= 0:
        return
    real = server._step_impl

    def slow():
        time.sleep(delay_s)
        return real()

    server._step_impl = slow


def run_phase(args, cfg, n_replicas: int, params=None):
    """One open-loop run against an ``n_replicas`` fleet; returns
    (metrics dict, shared weight arrays)."""
    from repro.serving.faults import OverloadError
    from repro.serving.fleet import FleetServer
    from repro.serving.server import (EngineConfig, OverloadPolicy,
                                      SamplingParams)

    fleet = FleetServer(
        cfg, num_replicas=n_replicas, num_slots=args.slots,
        capacity=args.capacity, seed=args.seed, params=params,
        engine_cfg=EngineConfig(cache_mode="paged",
                                page_size=args.page_size,
                                decode_chunk=args.chunk),
        overload=OverloadPolicy(max_queue_depth=args.queue_depth),
        pump=True)
    sp = SamplingParams(max_new_tokens=args.max_new, temperature=0.0)

    # absorb jit compiles on EVERY replica before the clock (and the
    # service-time floor) starts
    for r in fleet.replicas:
        r.server.submit("warmup " * 4,
                        SamplingParams(max_new_tokens=4)).result()
    for r in fleet.replicas:
        _slow_steps(r.server, args.step_delay_ms / 1000.0)

    arrivals = make_arrivals(args)
    plan = [(off, GROUPS[i % len(GROUPS)] + f"req {i}. ")
            for i, off in enumerate(arrivals)]
    done, rejected = [], [0]
    io_lock = threading.Lock()

    def client(shard):
        for off, prompt in shard:
            now = time.perf_counter() - t0
            if off > now:
                time.sleep(off - now)
            try:
                h = fleet.submit(prompt, sp)
            except OverloadError:
                with io_lock:
                    rejected[0] += 1
                continue
            with io_lock:
                done.append(h)

    t0 = time.perf_counter()
    clients = [threading.Thread(target=client,
                                args=(plan[c::args.clients],), daemon=True)
               for c in range(args.clients)]
    for c in clients:
        c.start()
    for c in clients:
        c.join()
    fleet.run_until_idle()
    wall = time.perf_counter() - t0
    st = fleet.stats()
    weights = fleet.params
    fleet.close()

    reqs = [h.request for h in done]
    comp = [r for r in reqs if r.status == "completed"]
    ttft = [r.first_token_s for r in reqs if r.first_token_s > 0]
    good = [r for r in comp if 0 < r.first_token_s <= args.slo_ttft]
    terminal = {"completed", "cancelled", "timed_out", "failed", "shed"}
    metrics = {
        "replicas": n_replicas,
        "admitted": len(reqs),
        "rejected": rejected[0],
        "completed": len(comp),
        "shed": sum(1 for r in reqs if r.status == "shed"),
        "wall_s": round(wall, 4),
        "ttft_p50_s": round(pctl(ttft, 0.50), 5),
        "ttft_p99_s": round(pctl(ttft, 0.99), 5),
        "goodput_req_s": round(len(good) / wall, 3),
        "goodput_tok_s": round(sum(r.output_tokens for r in good) / wall, 2),
        "affinity_hits": st["affinity_hits"],
        "affinity_rate": st["affinity_rate"],
        "spilled_admissions": st["spilled_admissions"],
        "routed_requests": st["routed_requests"],
        "prefix_hit_tokens": st["prefix_hit_tokens"],
        "all_terminal": all(r.status in terminal for r in reqs),
        "nothing_live_after_drain": (st["queued_requests"] == 0
                                     and st["live_requests"] == 0),
    }
    return metrics, weights


def migration_probe(args, cfg, params):
    """Crash one replica under K live sessions; turn 2 after failover must
    equal an uninterrupted single-server reference, bit for bit."""
    from repro.serving.fleet import FleetServer
    from repro.serving.server import (EngineConfig, LLMServer,
                                      SamplingParams)

    ecfg = EngineConfig(cache_mode="paged", page_size=args.page_size,
                        decode_chunk=args.chunk)
    sp = SamplingParams(max_new_tokens=args.max_new, temperature=0.0)

    ref = LLMServer(cfg, num_slots=2, capacity=128, seed=args.seed,
                    params=params, engine_cfg=ecfg)
    sess = ref.open_session()
    ref1 = sess.submit(T1, sp).result()
    ref2 = sess.submit(sess.text + DELTA, sp).result()
    ref.close()

    k = 3
    with FleetServer(cfg, num_replicas=2, num_slots=2, capacity=128,
                     seed=args.seed, params=params, engine_cfg=ecfg,
                     pump=True, digest_ttl_s=0.0) as fleet:
        sessions = [fleet.open_session() for _ in range(k)]
        turn1 = [fs.submit(T1, sp).result() for fs in sessions]
        victim = sessions[0].replica_index  # same prompt => all co-pinned
        srv = fleet.replicas[victim].server

        def boom():
            raise RuntimeError("fleet_bench: injected replica crash")

        srv._step_impl = boom
        deadline = time.monotonic() + 30.0
        while srv.pumping and time.monotonic() < deadline:
            time.sleep(0.01)
        turn2 = [fs.submit(fs.text + DELTA, sp).result() for fs in sessions]
        st = fleet.stats()
    return {
        "sessions": k,
        "victim_replica": victim,
        "turn1_identical": turn1 == [ref1] * k,
        "turn2_identical_after_migration": turn2 == [ref2] * k,
        "migrated_sessions": st["migrated_sessions"],
        "replicas_failed": st["replicas_failed"],
        "fleet_replicas_after": st["fleet_replicas"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet size N for the scaled phase")
    ap.add_argument("--requests", type=int, default=96,
                    help="total arrivals per phase")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (only with --trace poisson)")
    ap.add_argument("--trace", default="burst",
                    help="'burst' (default), 'poisson', or a JSON offsets "
                         "file — same formats as load_bench")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2,
                    help="slots PER replica (held fixed across phases)")
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--queue-depth", type=int, default=6,
                    help="OverloadPolicy.max_queue_depth PER replica")
    ap.add_argument("--step-delay-ms", type=float, default=30.0,
                    help="per-engine-step service-time floor (0 disables)")
    ap.add_argument("--slo-ttft", type=float, default=30.0)
    ap.add_argument("--goodput-gate", type=float, default=1.6,
                    help="required fleet/single goodput ratio")
    ap.add_argument("--out", default="results/fleet_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI gating")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new = 48, 8

    from repro.configs.registry import ARCHS

    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512, d_model=256, num_heads=8,
                                   head_dim=32, d_ff=512, num_layers=4)

    single, params = run_phase(args, cfg, 1)
    fleet_m, _ = run_phase(args, cfg, args.replicas, params=params)
    probe = migration_probe(args, cfg, params)

    ratio = (fleet_m["goodput_req_s"] / single["goodput_req_s"]
             if single["goodput_req_s"] > 0 else float("inf"))
    result = {
        "bench": "fleet_serving",
        "arch": args.arch,
        "trace": args.trace,
        "requests": args.requests,
        "slots_per_replica": args.slots,
        "queue_depth_per_replica": args.queue_depth,
        "step_delay_ms": args.step_delay_ms,
        "single_replica": single,
        "fleet": fleet_m,
        "goodput_ratio": round(ratio, 3),
        "migration_probe": probe,
    }
    checks = {
        "goodput_scales": ratio >= args.goodput_gate,
        "affinity_engaged": fleet_m["affinity_hits"] > 0,
        "all_requests_terminal": (single["all_terminal"]
                                  and fleet_m["all_terminal"]),
        "nothing_live_after_drain": (
            single["nothing_live_after_drain"]
            and fleet_m["nothing_live_after_drain"]),
        "migration_bit_identical": (
            probe["turn1_identical"]
            and probe["turn2_identical_after_migration"]),
        "all_sessions_migrated": (probe["migrated_sessions"]
                                  == probe["sessions"]),
    }
    result["checks"] = checks
    write_artifact(args.out, result, seed=args.seed)
    print(json.dumps(result, indent=2, default=str))
    if not all(checks.values()):
        raise SystemExit("fleet_bench: fleet gates FAILED")
    print(f"fleet_bench: OK (goodput x{ratio:.2f} at N={args.replicas}, "
          f"{fleet_m['affinity_hits']} affinity hits, migration "
          f"bit-identical) -> {args.out}")


if __name__ == "__main__":
    main()
