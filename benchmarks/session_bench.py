"""Session-serving benchmark: multi-turn conversation reuse + co-batching
through the session-oriented API (``repro.serving.server.LLMServer``) vs
fresh-prefill-per-turn.

Workload: the FAME multi-agent conversation shape (PAPER.md §memory
persistence) — W concurrent workflows, each a growing Planner / Actor /
Evaluator conversation. Every turn's prompt is the whole conversation so
far plus a short new instruction, and all W workflows submit their turn's
handle BEFORE any is drained, so the turns co-batch inside the same engine
steps. Two backends serve the identical token streams off one set of
weights:

* **sessions** — ``LLMServer`` with one session per workflow
  (``cache_mode="paged"``): turn N+1 restores turn N's end-of-generation
  state (radix-shared pages + the session's partial tail page, or the tail
  state snapshot on stateful archs) and prefills only the new instruction.
* **fresh** — the same scheduler in dense mode, sessionless: every turn
  re-prefills its full conversation from scratch (the pre-redesign
  behaviour). It replays the *exact token ids* the session engine served,
  so greedy outputs must be bit-identical.

Reported: per-turn time-to-first-token (admission prefill seconds) split by
turn index, TTFT speedup on turns >= 2 (the reuse turns), co-batching
(active slots per engine step in the session run), tail-reuse hit counters,
and the output-equality check:

    PYTHONPATH=src python benchmarks/session_bench.py [--smoke] [--arch A]

Acceptance floors (ISSUE 5): session TTFT on turns >= 2 must be <= 1/2 the
fresh-prefill TTFT, co-batching must keep > 1 active slot per engine step,
and greedy outputs must match token-for-token (CI runs ``--smoke``).

``--chaos`` (ISSUE 6) reruns the session side under a seeded
``FaultInjector`` (serving/faults.py) firing transient faults on ~5% of
decode / prefill-extend dispatches and page allocations. The timing floors
are replaced by graceful-degradation gates: every handle terminal, completed
outputs still token-identical to the fault-free fresh baseline, faults
actually injected, and p99 turn latency bounded (no deadlock, no stall).
"""
from __future__ import annotations

import argparse
import json
import time

from _artifact import write_artifact


SYSTEM_PROMPT = (
    "System: You are one of several cooperating agents in a FaaS-hosted MCP "
    "workflow. Shared rules: keep tool calls minimal, cite evidence for "
    "every claim, prefer cached tool outputs when the arguments are "
    "identical, and hand off to the evaluator after each action. ")

AGENT_TURNS = [
    ("planner", "Plan: decompose the user goal into the next tool call."),
    ("actor", "Act: execute the planned tool call and record the output."),
    ("evaluator", "Evaluate: check the output against the goal; pass or retry."),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--workflows", type=int, default=4,
                    help="concurrent conversations (one session each)")
    ap.add_argument("--rounds", type=int, default=3,
                    help="Planner/Actor/Evaluator rounds per workflow")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=768)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--floor", type=float, default=2.0,
                    help="required TTFT speedup on turns >= 2")
    ap.add_argument("--out", default="results/session_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI perf gating")
    ap.add_argument("--chaos", action="store_true",
                    help="inject seeded transient faults into the session "
                         "side and gate on graceful degradation instead of "
                         "the timing floors")
    ap.add_argument("--fault-rate", type=float, default=0.05,
                    help="per-dispatch fault probability in --chaos mode")
    args = ap.parse_args()
    if args.smoke:
        args.workflows, args.rounds = 3, 2

    from repro.configs.registry import ARCHS
    from repro.serving.scheduler import (EngineConfig, SamplingParams,
                                         Scheduler)
    from repro.serving.server import FaultInjector, LLMServer, RetryPolicy

    # a notch bigger than the test-suite smoke dims: prefill must be
    # compute-bound (not jit-dispatch-bound) for the A/B to measure the
    # algorithmic win rather than per-call overhead
    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512, d_model=256, num_heads=8,
                                   head_dim=32, d_ff=512, num_layers=4)
    injector = None
    if args.chaos:
        # seeded chaos on the session side only (the fresh baseline stays
        # clean — it IS the output reference); enough retry headroom that a
        # 5% transient rate dead-letters essentially nothing
        r = args.fault_rate
        injector = FaultInjector(seed=0, rates={"decode": r,
                                                "extend_paged": r,
                                                "pool.alloc": r})
    server = LLMServer(
        cfg, num_slots=args.slots, capacity=args.capacity,
        engine_cfg=EngineConfig(decode_chunk=args.chunk, cache_mode="paged",
                                page_size=args.page_size),
        injector=injector,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.005))
    fresh = Scheduler(
        cfg, num_slots=args.slots, capacity=args.capacity,
        params=server.params,
        engine_cfg=EngineConfig(decode_chunk=args.chunk))
    sp = SamplingParams(max_new_tokens=args.max_new)

    def run_conversations(record: bool):
        """One full pass of W growing conversations. ``record=False`` is the
        compile warm-up — DISTINCT conversation content (same shapes), so
        the measured pass exercises the session-tail path itself rather
        than finding its whole conversation pre-cached in the radix trie."""
        sessions = [server.open_session() for _ in range(args.workflows)]
        tag = "Warmup" if not record else "Workflow"
        convs = [SYSTEM_PROMPT + f"{tag} {w}: summarize incident {w}. "
                 for w in range(args.workflows)]
        ttft_sess, ttft_fresh, match, turn_idx = [], [], [], 0
        latencies, statuses = [], []
        for r in range(args.rounds):
            for role, ask in AGENT_TURNS:
                prompts = [convs[w] + f"[{role} r{r}] {ask} "
                           for w in range(args.workflows)]
                # submit EVERY workflow's turn before draining any — the
                # co-batching the session API exists for
                handles = [sessions[w].submit(prompts[w], sp)
                           for w in range(args.workflows)]
                server.run_until_idle()
                if record:
                    # chaos mode may dead-letter a turn: count it, gate on
                    # terminal status, and replay only completed turns
                    # against the fresh baseline (the output reference)
                    done = []
                    for w, h in enumerate(handles):
                        ttft_sess.append((turn_idx, h.request.prefill_s))
                        latencies.append(h.request.latency_s)
                        statuses.append(h.request.status)
                        if h.request.status == "completed":
                            done.append((w, h))
                    reqs = [(h, fresh.enqueue(prompts[w], sp,
                                              token_ids=h.request._ids))
                            for w, h in done]
                    fresh.run_until_drained()
                    for h, fr in reqs:
                        ttft_fresh.append((turn_idx, fr.prefill_s))
                        match.append(fr.output_text == h.request.output_text)
                for w in range(args.workflows):
                    convs[w] = sessions[w].text
                turn_idx += 1
        for s in sessions:
            s.close()
        return ttft_sess, ttft_fresh, match, latencies, statuses

    run_conversations(record=False)            # compile warm-up pass
    pre = server.stats()
    t0 = time.perf_counter()
    ttft_sess, ttft_fresh, match, latencies, statuses = \
        run_conversations(record=True)
    wall = time.perf_counter() - t0
    post = server.stats()
    d = lambda k: post.get(k, 0) - pre.get(k, 0)

    def mean_ttft(rows, lo):
        vals = [s for t, s in rows if t >= lo]
        return sum(vals) / max(len(vals), 1)

    reuse_ttft = mean_ttft(ttft_sess, 1)
    fresh_ttft = mean_ttft(ttft_fresh, 1)
    speedup = fresh_ttft / max(reuse_ttft, 1e-9)
    active_per_step = ((post["active_slots_per_step"] * post["engine_steps"]
                        - pre["active_slots_per_step"] * pre["engine_steps"])
                       / max(d("engine_steps"), 1))

    result = {
        "bench": "session_serving",
        "arch": args.arch,
        "workflows": args.workflows,
        "rounds": args.rounds,
        "turns_per_workflow": args.rounds * len(AGENT_TURNS),
        "num_slots": args.slots,
        "capacity": server.capacity,
        "max_new_tokens": args.max_new,
        "warm_wall_s": round(wall, 4),
        "ttft_turn1_s": round(
            sum(s for t, s in ttft_sess if t == 0)
            / max(sum(1 for t, _ in ttft_sess if t == 0), 1), 5),
        "sessions": {
            "ttft_turns_ge2_s": round(reuse_ttft, 5),
            "turn_prefix_hits": d("turn_prefix_hits"),
            "session_turns": d("session_turns"),
            "prefix_hit_tokens": d("prefix_hit_tokens"),
            "prompt_tokens": d("prompt_tokens"),
            "active_slots_per_step": round(active_per_step, 3),
            "stream_chunks": d("stream_chunks"),
            "truncated_tokens": d("truncated_tokens"),
        },
        "fresh_baseline": {
            "ttft_turns_ge2_s": round(fresh_ttft, 5),
        },
        "ttft_speedup_turns_ge2": round(speedup, 2),
    }
    if args.chaos:
        lat = sorted(latencies)
        p99 = lat[int(0.99 * (len(lat) - 1))] if lat else 0.0
        terminal = {"completed", "cancelled", "timed_out", "failed", "shed"}
        result["chaos"] = {
            "fault_rate": args.fault_rate,
            "faults_injected": sum(injector.injected.values()),
            "faults_by_site": dict(injector.injected),
            "dispatch_retries": d("dispatch_retries"),
            "admission_retries": d("admission_retries"),
            "dead_lettered": d("dead_lettered"),
            "turns_completed": statuses.count("completed"),
            "turns_total": len(statuses),
            "p99_turn_latency_s": round(p99, 4),
        }
        # graceful degradation replaces the timing floors: faults really
        # fired, every handle reached a terminal status (no deadlock), the
        # completed outputs are still bit-identical to the fault-free
        # baseline, and tail latency stayed bounded (no unbounded stall)
        result["checks"] = {
            "faults_injected_gt_0": sum(injector.injected.values()) > 0,
            "all_handles_terminal": all(s in terminal for s in statuses),
            "outputs_token_identical": all(match) and bool(match),
            "bounded_p99_turn_latency_s": p99 < 30.0,
        }
    else:
        result["checks"] = {
            f"ttft_speedup_ge_{args.floor:g}x": speedup >= args.floor,
            "co_batching_gt_1_slot_per_step": active_per_step > 1.0,
            "outputs_token_identical": all(match) and bool(match),
            "tail_reuse_on_every_later_turn":
                d("turn_prefix_hits")
                >= args.workflows * (args.rounds * len(AGENT_TURNS) - 1),
            "no_truncation": d("truncated_tokens") == 0,
        }
    write_artifact(args.out, result)
    print(json.dumps(result, indent=2))
    if not all(result["checks"].values()):
        raise SystemExit("session_bench: perf checks FAILED")
    print(f"session_bench: OK ({speedup:.1f}x TTFT on turns >= 2, "
          f"{active_per_step:.2f} active slots/step, outputs identical) "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
