"""Fig. 5 — Input/output LLM tokens per workflow invocation + LLM cost."""
from __future__ import annotations

from benchmarks.fame_common import CONFIG_ORDER, run_matrix


def main(matrix=None):
    matrix = matrix or run_matrix()
    print("fig5,app,input,query,config,in_tokens,out_tokens,llm_cents")
    for (app, config, inp), cell in sorted(matrix.items()):
        for qi in range(3):
            print(f"fig5,{app},{inp},Q{qi + 1},{config},{cell.in_tokens[qi]},"
                  f"{cell.out_tokens[qi]},{cell.llm_cents[qi]:.4f}")
    # headline: input-token reduction, session totals N -> best of {C,M,M+C}
    best = 0.0
    for app in ("RS", "LA"):
        for inp in {k[2] for k in matrix if k[0] == app}:
            n = sum(matrix[(app, "N", inp)].in_tokens)
            for c in ("C", "M", "M+C"):
                m = sum(matrix[(app, c, inp)].in_tokens)
                if n:
                    best = max(best, (n - m) / n)
    print(f"fig5_derived,max_input_token_reduction,{best * 100:.0f}%")
    return {"max_token_reduction": best}


if __name__ == "__main__":
    main()
