"""Fig. 5 — Input/output LLM tokens per workflow invocation + LLM cost.

Under ``--llm jax`` the token columns are *billed* tokens from the real
serving stack: session continuations bill only their delta, cache-hit tool
injections bill zero (EXPERIMENTS.md §Billing)."""
from __future__ import annotations

import argparse
import os
import sys

try:
    from benchmarks import fame_common as fc
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import fame_common as fc


def main(matrix=None, argv=None):
    args = None
    if matrix is None:
        ap = fc.add_common_args(argparse.ArgumentParser(description=__doc__),
                                default_out="results/fame_fig5.json")
        args = ap.parse_args(argv if argv is not None else [])
        matrix, _ = fc.matrix_from_args(args)
    print("fig5,app,input,query,config,in_tokens,out_tokens,llm_cents")
    for (app, config, inp), cell in sorted(matrix.items()):
        for qi in range(3):
            print(f"fig5,{app},{inp},Q{qi + 1},{config},{cell.in_tokens[qi]},"
                  f"{cell.out_tokens[qi]},{cell.llm_cents[qi]:.4f}")
    # headline: input-token reduction, session totals N -> best of {C,M,M+C}
    best = 0.0
    for app in ("RS", "LA"):
        for inp in {k[2] for k in matrix if k[0] == app}:
            n = sum(matrix[(app, "N", inp)].in_tokens)
            for c in ("C", "M", "M+C"):
                m = sum(matrix[(app, c, inp)].in_tokens)
                if n:
                    best = max(best, (n - m) / n)
    print(f"fig5_derived,max_input_token_reduction,{best * 100:.0f}%")
    out = {"max_token_reduction": best}
    if args is not None:
        from _artifact import write_artifact
        write_artifact(args.out, dict(out, matrix=fc.matrix_to_dict(matrix)))
    return out


if __name__ == "__main__":
    main(argv=sys.argv[1:])
