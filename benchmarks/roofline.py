"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads results/dryrun_single_pod.json (and optionally multi-pod) and derives,
per (arch × shape):
    compute term    = HLO_FLOPs/dev   / peak_FLOP/s        (197 TF bf16, v5e)
    memory term     = HLO_bytes/dev   / HBM_bw             (819 GB/s)
    collective term = coll_bytes/dev  / link_bw            (50 GB/s ICI)
plus MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference) with N = active params,
the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant bottleneck, and
the roofline fraction (useful-compute time / dominant term — the MFU bound).
"""
from __future__ import annotations

import json
import os
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}

IMPROVE_HINTS = {
    ("compute", "train"): "raise per-chip math: fewer remat recomputes (selective policy) or larger microbatch",
    ("compute", "prefill"): "fuse attention (Pallas flash kernel) and drop masked-block waste",
    ("compute", "decode"): "decode is tiny-FLOP; batch more sequences per step",
    ("memory", "train"): "cut activation traffic: fused kernels + bf16 collectives + selective remat",
    ("memory", "prefill"): "stream KV blocks through VMEM (flash kernel) instead of HBM round-trips",
    ("memory", "decode"): "KV-cache reads dominate: quantize cache (int8) or shrink window",
    ("collective", "train"): "overlap FSDP gathers with compute; reduce-scatter grads in bf16",
    ("collective", "prefill"): "shard KV heads instead of gathering weights per layer",
    ("collective", "decode"): "weight-gather bound at small batch: replicate hot weights or raise batch",
}


def analyze(results_path: str = "results/dryrun_single_pod.json"):
    data = json.load(open(results_path))
    rows = []
    for r in sorted(data["results"], key=lambda x: (x["arch"], x["shape"])):
        dev = r["devices"]
        flops = r["flops"]
        byts_max = r["bytes_accessed"]
        byts_min = r.get("bytes_min", byts_max)
        coll = r["collectives"]["total_bytes"]
        t_c = flops / PEAK
        # HBM traffic bracket: bytes_min counts only genuine data movers
        # (fusion-optimistic, ~TPU reality); bytes_accessed counts every
        # op boundary (the CPU backend wraps each op in its own fusion, a
        # strong overcount). Dominance/MFU use the optimistic bound.
        t_m = byts_min / HBM
        t_m_max = byts_max / HBM
        t_x = coll / ICI
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dominant = max(terms, key=terms.get)
        n_active = r["active_params"]
        tokens = SHAPE_TOKENS[r["shape"]] * (r.get("global_batch_mult", 1))
        mult = 6 if r["phase"] == "train" else 2
        model_flops = mult * n_active * tokens / dev
        useful_ratio = model_flops / flops if flops else 0.0
        t_useful = model_flops / PEAK
        mfu_bound = t_useful / max(terms.values()) if max(terms.values()) else 0.0
        mem = r.get("memory", {})
        hbm_gb = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)) / 1e9
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": t_c, "memory_s": t_m, "memory_s_max": t_m_max,
            "collective_s": t_x,
            "dominant": dominant,
            "model_flops_dev": model_flops, "hlo_flops_dev": flops,
            "useful_ratio": useful_ratio, "mfu_bound": mfu_bound,
            "hbm_gb_dev": hbm_gb,
            "hint": IMPROVE_HINTS.get((dominant, r["phase"]), ""),
            "phase": r["phase"],
        })
    return rows


def to_markdown(rows):
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "useful/HLO | MFU bound | HBM GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound'] * 100:.1f}% | {r['hbm_gb_dev']:.1f} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single_pod.json"
    rows = analyze(path)
    print("roofline,arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,mfu_bound_pct")
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['mfu_bound'] * 100:.2f}")
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
