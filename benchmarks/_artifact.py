"""Shared bench-artifact writer: provenance-stamped ``results/*.json``.

Every benchmark in this directory writes its result JSON through
``write_artifact``, which stamps a ``meta`` block before writing:

    "meta": {
        "commit": "<git HEAD sha, or null outside a checkout>",
        "config_argv": [...],        # the exact CLI flags of this run
        "seed": 0,                   # the bench's RNG seed (null if none)
        "schema_version": 1,
        "written_at": "2026-01-01T00:00:00Z"
    }

That makes artifacts uploaded from different PRs / branches comparable:
two ``load_bench.json`` files can be diffed knowing which commit, flags
and seed produced each. Bump ``SCHEMA_VERSION`` when a bench's payload
shape changes incompatibly, so downstream tooling can dispatch.

Benches run as scripts from this directory (``python benchmarks/x.py``),
so a plain ``from _artifact import write_artifact`` resolves everywhere —
including the fame fig harnesses, which previously used the bare
``repro.fame.trace.write_artifact`` (kept for compatibility, unstamped).
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import sys
from typing import Optional

SCHEMA_VERSION = 1


def provenance(seed: Optional[int] = None) -> dict:
    """The meta block: commit + argv + seed + schema version."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None                   # not a checkout / no git binary
    return {
        "commit": commit,
        "config_argv": list(sys.argv[1:]),
        "seed": seed,
        "schema_version": SCHEMA_VERSION,
        "written_at": datetime.datetime.now(datetime.timezone.utc)
                      .strftime("%Y-%m-%dT%H:%M:%SZ"),
    }


def write_artifact(path: str, payload: dict, *,
                   seed: Optional[int] = None) -> dict:
    """Stamp ``payload`` with the provenance meta block and write it to
    ``path`` (directories created as needed). Returns the stamped payload
    (also what the caller should print, so stdout matches the file)."""
    stamped = dict(payload)
    stamped["meta"] = provenance(seed)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(stamped, f, indent=2, default=str)
    print(f"wrote {path}")
    return stamped
