"""Speculative-decode benchmark: drafter-free n-gram speculation A/B.

Workload: the FAME copy-heavy decode shape (PAPER.md — research-paper
summarization / log analytics) — agent answers that re-surface spans already
sitting in the context (tool results, fetched text, log lines), exactly the
traffic where "Network and Systems Performance Characterization of
MCP-Enabled LLM Agents" (arXiv 2511.07426) measures token-generation time
dwarfing MCP overhead. The same request stream runs through engines sharing
one set of weights:

* **spec**  — ``EngineConfig(spec_len=N)``: a host-side n-gram lookup over
  prompt + generated tokens drafts up to N continuation tokens per engine
  step; ONE jit'd verify forward scores every draft position and commits the
  accepted prefix (greedy: exact match, bit-identical output).
* **base**  — ``spec_len=0``: the PR-1/2 chunked decode loop.

Both dense and paged cache modes are measured; greedy outputs must be
bit-identical between spec and base within each mode.

Reported: decode tokens/sec (wall-clock: warm drain wall minus prefill
time), speedup, draft acceptance rate, verify steps:

    PYTHONPATH=src python benchmarks/spec_bench.py [--smoke] [--arch A]

Acceptance floor (ISSUE 3): spec decode >= 1.8x base tokens/sec at >= 60%
draft acceptance on the copy-heavy workload, outputs bit-identical in dense
AND paged modes (CI runs ``--smoke`` as a perf gate).
"""
from __future__ import annotations

import argparse
import json
import os
import time


LOG_LINES = (
    "2026-07-28T09:14:02 gateway ERROR 429 rate limit exceeded for "
    "tool=search retry_after=30s trace=ab12f9; "
    "2026-07-28T09:14:03 runner WARN cold start 812ms for fn=summarize "
    "mem=512MB; "
    "2026-07-28T09:14:05 gateway ERROR 429 rate limit exceeded for "
    "tool=fetch retry_after=30s trace=ab1301; ")


def make_workload(n_agents: int):
    """Prompt stream: each agent gets the shared tool-result/log context and
    an instruction whose faithful answer copies spans of it verbatim."""
    return [f"[agent {i}] Analyze the log and list every failing line "
            f"verbatim, then name the failing tools: " + LOG_LINES * 3
            for i in range(n_agents)]


def run_engine(engine, prompts, max_new):
    """One cold pass (compiles + drafter warm-path shapes), then a warm
    measured pass. Engine counters are lifetime totals, so the measured pass
    reports deltas."""
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    engine.run_until_drained()
    cold = engine.stats()
    reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    t0 = time.perf_counter()
    engine.run_until_drained()
    wall = time.perf_counter() - t0
    warm = engine.stats()
    d = lambda k: warm.get(k, 0) - cold.get(k, 0)
    prefill_s = sum(r.prefill_s for r in reqs)
    decode_s = max(wall - prefill_s, 1e-9)
    toks = d("decode_tokens")
    return {
        "warm_wall_s": round(wall, 4),
        "prefill_s": round(prefill_s, 4),
        "decode_wall_s": round(decode_s, 4),
        "decode_tokens": toks,
        "decode_tok_s": round(toks / decode_s, 2),
        "host_syncs": d("host_syncs"),
        "verify_steps": d("verify_steps"),
        "decode_chunks": d("decode_chunks"),
        "draft_tokens": d("draft_tokens"),
        "accepted_tokens": d("accepted_tokens"),
        "acceptance_rate": round(d("accepted_tokens")
                                 / max(d("draft_tokens"), 1), 4),
    }, [r.output_text for r in reqs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help="full-attention arch (batched verify path)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--agents", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=160)
    ap.add_argument("--spec-len", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--out", default="results/spec_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI perf gating")
    args = ap.parse_args()
    if args.smoke:
        # decode-heavy enough that the wall-clock A/B is stable: the spec
        # engine's decode phase is several times shorter than base, so short
        # runs would put CI-runner noise right against the 1.8x floor
        args.agents, args.max_new = 4, 176

    from repro.configs.registry import ARCHS
    from repro.serving.engine import EngineConfig, ServingEngine

    # prefix_bench-sized dims: decode must be compute-bound (not
    # jit-dispatch-bound) so the A/B measures fewer-forwards-per-token, not
    # per-call overhead
    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   vocab_size=512, d_model=256, num_heads=8,
                                   head_dim=32, d_ff=512, num_layers=4)
    prompts = make_workload(args.agents)

    results, outputs = {}, {}
    params = None
    for mode in ("dense", "paged"):
        for tag, spec_len in (("spec", args.spec_len), ("base", 0)):
            eng = ServingEngine(
                cfg, num_slots=args.slots, capacity=args.capacity,
                params=params,
                engine_cfg=EngineConfig(decode_chunk=args.chunk,
                                        cache_mode=mode,
                                        spec_len=spec_len))
            params = eng.params
            results[f"{mode}_{tag}"], outputs[f"{mode}_{tag}"] = \
                run_engine(eng, prompts, args.max_new)

    speedup = {m: round(results[f"{m}_spec"]["decode_tok_s"]
                        / max(results[f"{m}_base"]["decode_tok_s"], 1e-9), 2)
               for m in ("dense", "paged")}
    acc = results["dense_spec"]["acceptance_rate"]

    result = {
        "bench": "speculative_decode",
        "arch": args.arch,
        "num_slots": args.slots,
        "capacity": args.capacity,
        "spec_len": args.spec_len,
        "requests": len(prompts),
        "max_new_tokens": args.max_new,
        **{k: v for k, v in results.items()},
        "decode_speedup_dense": speedup["dense"],
        "decode_speedup_paged": speedup["paged"],
        "checks": {
            # the ISSUE-3 gates: >= 1.8x decode tok/s at >= 60% acceptance,
            # greedy outputs bit-identical in both cache modes
            "dense_speedup_ge_1_8x": speedup["dense"] >= 1.8,
            "paged_speedup_ge_1_8x": speedup["paged"] >= 1.8,
            "acceptance_ge_60pct": acc >= 0.60,
            "dense_outputs_bit_identical":
                outputs["dense_spec"] == outputs["dense_base"],
            "paged_outputs_bit_identical":
                outputs["paged_spec"] == outputs["paged_base"],
        },
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    if not all(result["checks"].values()):
        raise SystemExit("spec_bench: perf checks FAILED")
    print(f"spec_bench: OK ({speedup['dense']:.1f}x dense / "
          f"{speedup['paged']:.1f}x paged decode vs non-speculative, "
          f"{acc:.0%} draft acceptance, outputs identical) -> {args.out}")


if __name__ == "__main__":
    main()
