"""Speculative-decode benchmark: drafter-free n-gram speculation A/B.

Workload: the FAME copy-heavy decode shape (PAPER.md — research-paper
summarization / log analytics) — agent answers that re-surface spans already
sitting in the context (tool results, fetched text, log lines), exactly the
traffic where "Network and Systems Performance Characterization of
MCP-Enabled LLM Agents" (arXiv 2511.07426) measures token-generation time
dwarfing MCP overhead. The same request stream runs through engines sharing
one set of weights:

* **spec**  — ``EngineConfig(spec_len=N)``: a host-side n-gram lookup over
  prompt + generated tokens drafts up to N continuation tokens per engine
  step; ONE jit'd verify forward scores every draft position and commits the
  accepted prefix (greedy: exact match, bit-identical output).
* **base**  — ``spec_len=0``: the PR-1/2 chunked decode loop.

Both dense and paged cache modes are measured; greedy outputs must be
bit-identical between spec and base within each mode.

Reported: decode tokens/sec (wall-clock: warm drain wall minus prefill
time), speedup, draft acceptance rate, verify steps:

    PYTHONPATH=src python benchmarks/spec_bench.py [--smoke] [--arch A]

Acceptance floor (ISSUE 3): spec decode >= 1.8x base tokens/sec at >= 60%
draft acceptance on the copy-heavy workload, outputs bit-identical in dense
AND paged modes (CI runs ``--smoke`` as a perf gate).
"""
from __future__ import annotations

import argparse
import json
import time

from _artifact import write_artifact


LOG_LINES = (
    "2026-07-28T09:14:02 gateway ERROR 429 rate limit exceeded for "
    "tool=search retry_after=30s trace=ab12f9; "
    "2026-07-28T09:14:03 runner WARN cold start 812ms for fn=summarize "
    "mem=512MB; "
    "2026-07-28T09:14:05 gateway ERROR 429 rate limit exceeded for "
    "tool=fetch retry_after=30s trace=ab1301; ")

FLAP_LINES = "err 429; ok 200; "


def make_workload(n_agents: int, kind: str):
    """Prompt stream: each agent gets a shared tool-result/log context and
    an instruction whose faithful answer copies spans of it verbatim.
    ``copy``: long-period log lines (the attention-arch shape — verbatim
    span re-surfacing). ``flap``: short-period status flapping (the
    stateful-arch shape — a recurrent state locked into the cycle keeps
    emitting it, which is exactly what the n-gram drafter predicts)."""
    if kind == "copy":
        ctx = LOG_LINES * 3
        ask = "Analyze the log and list every failing line verbatim, " \
              "then name the failing tools: "
    else:
        ctx = FLAP_LINES * 20
        ask = "The status stream below flaps; continue it verbatim: "
    return [f"[agent {i}] {ask}" + ctx for i in range(n_agents)]


def run_engine(engine, prompts, max_new):
    """One cold pass (compiles + drafter warm-path shapes), then a warm
    measured pass. Engine counters are lifetime totals, so the measured pass
    reports deltas."""
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    engine.run_until_drained()
    cold = engine.stats()
    reqs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    t0 = time.perf_counter()
    engine.run_until_drained()
    wall = time.perf_counter() - t0
    warm = engine.stats()
    d = lambda k: warm.get(k, 0) - cold.get(k, 0)
    prefill_s = sum(r.prefill_s for r in reqs)
    decode_s = max(wall - prefill_s, 1e-9)
    toks = d("decode_tokens")
    return {
        "warm_wall_s": round(wall, 4),
        "prefill_s": round(prefill_s, 4),
        "decode_wall_s": round(decode_s, 4),
        "decode_tokens": toks,
        "decode_tok_s": round(toks / decode_s, 2),
        "host_syncs": d("host_syncs"),
        "verify_steps": d("verify_steps"),
        "decode_chunks": d("decode_chunks"),
        "draft_tokens": d("draft_tokens"),
        "accepted_tokens": d("accepted_tokens"),
        "acceptance_rate": round(d("accepted_tokens")
                                 / max(d("draft_tokens"), 1), 4),
    }, [r.output_text for r in reqs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help="any registry arch — full attention verifies over "
                         "its KV cache, stateful archs (recurrentgemma / "
                         "xlstm / mixtral) through staged per-position "
                         "states + accept-length rewind")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--agents", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=160)
    ap.add_argument("--spec-len", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--num-layers", type=int, default=4,
                    help="reduced-config depth (use a multiple of the "
                         "arch's block pattern length)")
    ap.add_argument("--modes", default="dense,paged",
                    help="comma-separated cache modes to A/B")
    ap.add_argument("--floor", type=float, default=1.8,
                    help="CI gate: min decode tok/s speedup per mode")
    ap.add_argument("--min-accept", type=float, default=0.60,
                    help="CI gate: min draft acceptance rate")
    ap.add_argument("--workload", choices=("copy", "flap"), default=None,
                    help="copy: long-period log lines (attention copy "
                         "shape); flap: short-period status cycle "
                         "(stateful-arch shape). Default: flap for "
                         "stateful archs, copy otherwise")
    ap.add_argument("--tie-embeddings", action="store_true",
                    help="tie embed/unembed in the reduced config. Random "
                         "(untrained) stateful archs only produce the "
                         "copyable outputs this bench measures when the "
                         "residual stream reaches the unembed — trained "
                         "models copy on their own; this keeps the A/B in "
                         "the same acceptance regime (use for "
                         "recurrentgemma)")
    ap.add_argument("--out", default="results/spec_bench.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI perf gating")
    args = ap.parse_args()
    if args.smoke:
        # decode-heavy enough that the wall-clock A/B is stable: the spec
        # engine's decode phase is several times shorter than base, so short
        # runs would put CI-runner noise right against the floor
        args.agents, args.max_new = 4, 176

    from repro.configs.registry import ARCHS
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.kvpool import supports_paged

    # prefix_bench-sized dims: decode must be compute-bound (not
    # jit-dispatch-bound) so the A/B measures fewer-forwards-per-token, not
    # per-call overhead
    over = dict(vocab_size=512, d_model=256, num_heads=8, head_dim=32,
                d_ff=512, num_layers=args.num_layers)
    if args.tie_embeddings:
        over["tie_embeddings"] = True
    cfg = ARCHS[args.arch].reduced(dtype="float32", param_dtype="float32",
                                   **over)
    if args.workload is None:
        args.workload = "copy" if supports_paged(cfg)[0] else "flap"
    prompts = make_workload(args.agents, args.workload)
    modes = [m.strip() for m in args.modes.split(",") if m.strip()]

    results, outputs = {}, {}
    params = None
    for mode in modes:
        for tag, spec_len in (("spec", args.spec_len), ("base", 0)):
            eng = ServingEngine(
                cfg, num_slots=args.slots, capacity=args.capacity,
                params=params,
                engine_cfg=EngineConfig(decode_chunk=args.chunk,
                                        cache_mode=mode,
                                        spec_len=spec_len))
            params = eng.params
            results[f"{mode}_{tag}"], outputs[f"{mode}_{tag}"] = \
                run_engine(eng, prompts, args.max_new)

    speedup = {m: round(results[f"{m}_spec"]["decode_tok_s"]
                        / max(results[f"{m}_base"]["decode_tok_s"], 1e-9), 2)
               for m in modes}
    acc = results[f"{modes[0]}_spec"]["acceptance_rate"]

    checks = {"acceptance_floor": acc >= args.min_accept}
    for m in modes:
        checks[f"{m}_speedup_floor"] = speedup[m] >= args.floor
        checks[f"{m}_outputs_bit_identical"] = \
            outputs[f"{m}_spec"] == outputs[f"{m}_base"]
    result = {
        "bench": "speculative_decode",
        "arch": args.arch,
        "workload": args.workload,
        "num_slots": args.slots,
        "capacity": args.capacity,
        "spec_len": args.spec_len,
        "requests": len(prompts),
        "max_new_tokens": args.max_new,
        "speedup_floor": args.floor,
        "acceptance_floor": args.min_accept,
        **{k: v for k, v in results.items()},
        **{f"decode_speedup_{m}": speedup[m] for m in modes},
        "checks": checks,
    }
    write_artifact(args.out, result)
    print(json.dumps(result, indent=2))
    if not all(result["checks"].values()):
        raise SystemExit("spec_bench: perf checks FAILED")
    print("spec_bench: OK ("
          + " / ".join(f"{speedup[m]:.1f}x {m}" for m in modes)
          + f" decode vs non-speculative, {acc:.0%} draft acceptance, "
            f"outputs identical) -> {args.out}")


if __name__ == "__main__":
    main()
