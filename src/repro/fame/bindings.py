"""Serving bindings: the paper's ReAct agents on the real ``LLMServer``.

Mirrors ``core/agents.ReActAgents`` handler-for-handler — same prompts, same
payload mutations, same oracle-rule decisions — but every agent LLM call is
also a *real request* on the serving stack:

* **Memory configs (M, M+C)** get one persistent server session per workflow
  invocation chain. Each agent turn appends only its *delta* (user line, tool
  refs, role tag) to the session tail — memory persistence/injection (§3.2)
  becomes token-level session continuation: the engine restores the retained
  tail instead of re-prefilling the conversation, and the client is billed
  only the delta tokens.
* **Stateless configs (E, N, C)** re-submit the full rendered context every
  call, exactly like a client that re-sends its history (config N's token
  bloat in Fig. 5).

Decisions (plans, tool calls, verdicts) come from the apps' scripted oracle
rules over the *semantic* context — identical strings to oracle mode, so
workflow statuses are deterministic and equal across backends — while the
served stream is a clipped canonical rendering of the same conversation (tiny
untrained checkpoints would otherwise decode garbage into the control flow).
Failures surface as the PR-6 taxonomy: a FAILED turn raises ``request.error``
into the state machine's per-state Retry; a TIMED_OUT turn raises
``DeadlineExceeded``; exhausted retries dead-letter the workflow into
``FailState``.
"""
from __future__ import annotations

import json
import time
from typing import Callable, List, Optional, Union

from repro.core.agents import (ACTOR_MEMORY_PROMPT, ACTOR_PROMPT,
                               EVALUATOR_PROMPT, PLANNER_PROMPT, _context)
from repro.core.faas import PRICING
from repro.core.mcp import rpc_call, rpc_tools_list
from repro.core.memory import MemoryEntry
from repro.core.telemetry import emit
from repro.fame.toolflow import canonical_tool_message, clip_content
from repro.fame.trace import TurnRecord
from repro.serving.faults import DeadlineExceeded, RequestFault, ShedError


class ChainBinding:
    """One workflow invocation chain's conversation on the server.

    ``persistent=True`` opens a server session and drives it by token-level
    continuation; ``persistent=False`` submits sessionless full prompts.
    """

    def __init__(self, rt, chain_id: str, *, persistent: bool):
        self.rt = rt
        self.chain_id = chain_id
        self.persistent = persistent
        self.session = rt.server.open_session() if persistent else None
        self.turn_idx = 0

    @property
    def first_turn(self) -> bool:
        return self.persistent and self.turn_idx == 0

    def turn(self, role: str, delta: str,
             full_prompt: Union[str, Callable[[], str]],
             ctx=None) -> TurnRecord:
        """Submit one agent turn; blocks (via the fusion driver) until the
        request is terminal. Raises the taxonomy error on FAILED/TIMED_OUT."""
        rt = self.rt
        server = rt.server
        params = rt.turn_params()
        billed = None
        if self.persistent:
            base = self.session.text
            continuation = bool(base)
            prompt = base + delta
            if continuation:
                # server.tokenizer works on both a single LLMServer and a
                # FleetServer front (which has no single .engine)
                billed = len(server.tokenizer.encode(delta, bos=False))
            sid = self.session.sid
            submit = lambda: server.submit(prompt, params, session=sid)
        else:
            continuation = False
            prompt = full_prompt() if callable(full_prompt) else full_prompt
            submit = lambda: server.submit(prompt, params)
        t0 = time.perf_counter()
        h = rt.driver.call(submit)
        wall = time.perf_counter() - t0
        req = h.request
        self.turn_idx += 1
        if billed is None:
            billed = req.prompt_tokens
        rec = TurnRecord(
            kind="turn", role=role, chain_id=self.chain_id, rid=req.rid,
            status=req.status,
            error_type=type(req.error).__name__ if req.error else "",
            prompt_tokens=req.prompt_tokens, billed_tokens=billed,
            prefix_hit_tokens=req.prefix_hit_tokens,
            output_tokens=req.output_tokens, wall_s=wall,
            session_turn=self.turn_idx if self.persistent else 0,
            continuation=continuation)
        rt.meter.record(rec)
        if ctx is not None:
            ctx.charge(wall)
            emit("llm", f"fame-{role}", ctx.now() - wall, ctx.now(),
                 input_tokens=billed, output_tokens=req.output_tokens,
                 cost_cents=PRICING.llm_cost(billed, req.output_tokens),
                 rid=req.rid, prefix_hit_tokens=req.prefix_hit_tokens,
                 continuation=continuation)
        if req.status == "failed":
            raise req.error if req.error is not None else \
                RequestFault(f"turn rid={req.rid} failed")
        if req.status == "timed_out":
            raise req.error if req.error is not None else \
                DeadlineExceeded(f"turn rid={req.rid} exceeded its deadline")
        if req.status == "shed":
            raise req.error if req.error is not None else \
                ShedError(f"turn rid={req.rid} shed under overload")
        return rec

    def close(self):
        if self.session is not None and not self.session.closed:
            self.session.close()


class ServingAgents:
    """Planner/Actor/Evaluator FaaS handlers bound to a
    ``fame.runtime.WorkflowServingRuntime``."""

    def __init__(self, runtime):
        self.rt = runtime

    # ---- served-view rendering (clipped mirror of agents._context) ---------
    def _served_message(self, m: dict, ctx=None) -> str:
        rt = self.rt
        role = m.get("role", "?")
        if role == "tool":
            if rt.toolflow.enabled:
                return rt.toolflow.ref_line(m.get("tool"),
                                            m.get("arguments", {}))
            return canonical_tool_message(m.get("tool"),
                                          m.get("arguments", {}),
                                          m.get("content", ""),
                                          clip=rt.stream_clip)
        return f"[{role}] {clip_content(m.get('content', ''), rt.stream_clip)}"

    def served_context(self, payload: dict) -> str:
        rt = self.rt
        parts = []
        if payload.get("client_history"):
            parts.append("[CLIENT HISTORY]\n" + payload["client_history"])
        if payload.get("memory_context"):
            parts.append(clip_content(payload["memory_context"],
                                      2 * rt.stream_clip))
        if payload.get("feedback"):
            parts.append("[EVALUATOR FEEDBACK]\n" + payload["feedback"])
        parts.append("[USER REQUEST]\n" + payload.get("user_request", ""))
        if payload.get("messages"):
            parts.append("[MESSAGES]\n" + "\n".join(
                self._served_message(m) for m in payload["messages"]))
        return "\n\n".join(parts)

    # ------------------------------------------------------------- Planner
    def planner_handler(self, payload: dict, ctx) -> dict:
        rt = self.rt
        memory_context = ""
        if rt.config.agentic_memory:
            ctx.charge(0.012)                                  # DynamoDB query
            memory_context = rt.memory.render_context(
                payload["session_id"], t=ctx.now())
        tool_descs: List[str] = []
        for fn_name in rt.mcp_function_names():
            resp = ctx.invoke(fn_name, {"body": rpc_tools_list()})
            for t in resp["body"]["result"]["tools"]:
                tool_descs.append(f"- {t['name']}: {t['description']}")
        payload = dict(payload, memory_context=memory_context)
        system = PLANNER_PROMPT.format(tools_description="\n".join(tool_descs))
        plan_json = rt.decide("planner", system, _context(payload))
        chain = rt.chain_for(payload)
        delta = []
        if chain.first_turn:
            delta.append("[TOOLS]\n" + "\n".join(tool_descs) + "\n")
        if payload.get("feedback"):
            delta.append("[EVALUATOR FEEDBACK]\n"
                         + payload["feedback"] + "\n")
        if payload.get("iteration", 1) == 1:
            delta.append(f"[user] {payload.get('user_request', '')}\n")
        delta.append("[plan]\n")
        chain.turn("planner", "".join(delta),
                   lambda: system + "\n\n" + self.served_context(payload),
                   ctx=ctx)
        messages = list(payload.get("messages", []))
        messages.append({"role": "planner", "content": plan_json})
        return dict(payload, plan_json=plan_json, messages=messages,
                    memory_context=memory_context)

    # --------------------------------------------------------------- Actor
    def actor_handler(self, payload: dict, ctx) -> dict:
        rt = self.rt
        system = ACTOR_PROMPT.format(plan_json=payload.get("plan_json", ""))
        if rt.config.agentic_memory:
            system += "\n" + ACTOR_MEMORY_PROMPT
        chain = rt.chain_for(payload)
        messages = list(payload.get("messages", []))
        pending_delta = "[act]\n"
        while True:
            view = dict(payload, messages=messages)
            text = rt.decide("actor", system, _context(view))
            try:
                decision = json.loads(text)
            except json.JSONDecodeError:
                decision = {"final": text}
            chain.turn("actor", pending_delta,
                       lambda v=view: system + "\n\n" + self.served_context(v),
                       ctx=ctx)
            calls = decision.get("tool_calls")
            if not calls:
                final = decision.get("final", "")
                break
            served_lines = []
            for call in calls:
                tool = call["tool"]
                args = call.get("arguments", {})
                fn_name = rt.resolve_tool_function(tool)
                hits_before = rt.cache.hits
                resp = ctx.invoke(fn_name, {"body": rpc_call(tool, args)})
                body = resp["body"]
                if "error" in body:
                    content = f"ERROR: {body['error']['message']}"
                else:
                    content = body["result"]["content"][0]["text"]
                cache_hit = rt.cache.hits > hits_before
                messages.append({"role": "tool", "tool": tool,
                                 "arguments": args, "content": content})
                if rt.toolflow.enabled:
                    rt.toolflow.inject(tool, args, content,
                                       cache_hit=cache_hit,
                                       chain_id=chain.chain_id, ctx=ctx)
                    served_lines.append(rt.toolflow.ref_line(tool, args))
                else:
                    served_lines.append(canonical_tool_message(
                        tool, args, content, clip=rt.stream_clip))
            pending_delta = "\n".join(served_lines) + "\n[act]\n"
        messages = messages + [{"role": "actor", "content": final}]
        return dict(payload, result_json=final, messages=messages)

    # ----------------------------------------------------------- Evaluator
    def evaluator_handler(self, payload: dict, ctx) -> dict:
        rt = self.rt
        system = EVALUATOR_PROMPT.format(
            plan_json=payload.get("plan_json", ""),
            result_json=payload.get("result_json", ""),
            iteration_count=payload.get("iteration", 1),
            max_iterations=payload.get("max_iterations", 3))
        text = rt.decide("evaluator", system, _context(payload))
        try:
            verdict = json.loads(text)
        except json.JSONDecodeError:
            verdict = {"success": False, "needs_retry": False,
                       "reason": "unparseable evaluator output"}
        chain = rt.chain_for(payload)
        chain.turn("evaluator", "[eval]\n",
                   lambda: system + "\n\n" + self.served_context(payload),
                   ctx=ctx)
        if rt.config.agentic_memory:
            ctx.charge(0.010)                                   # DynamoDB write
            rt.memory.persist(MemoryEntry(
                session_id=payload["session_id"],
                invocation_id=payload["invocation_id"],
                user_request=payload.get("user_request", ""),
                messages=payload.get("messages", []),
                final_response=payload.get("result_json", "")), t=ctx.now())
        return dict(payload, verdict=verdict,
                    feedback=verdict.get("feedback", ""))
