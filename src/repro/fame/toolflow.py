"""Tool-output caching composed with radix prefix sharing (§3.3.2).

The seed's ``CacheManager`` removes the *tool execution*; this layer removes
the *re-prefill*. Every MCP result enters the serving layer as a standalone
"injection" request whose text is canonical — tool name, cache-key argument
rendering (``toolcache.canonical_args_text``), deterministically clipped
content — so a cached result re-injected later is token-identical from stream
position 0 and radix-hits the pages adopted by the first injection instead of
prefilling again. The Actor's conversation then carries only a short
``[ToolRef …]`` line; the payload bytes live once, in shared KV pages.

Billing follows the composition: a cache-miss injection bills its full prompt
(new content shipped to the model); a cache-hit bills zero (content already
resident server-side).
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.telemetry import emit
from repro.core.toolcache import cache_key, canonical_args_text
from repro.fame.trace import ServingMeter, TurnRecord

INJECT_SUFFIX = "\n[ack]\n"


def clip_content(content: str, limit: int) -> str:
    """Deterministic clipping for the served stream (the oracle's semantic
    context keeps the full text). Must be stable across re-injections."""
    if limit <= 0 or len(content) <= limit:
        return content
    return content[:limit] + f"…[clipped {len(content) - limit} chars]"


def canonical_tool_message(tool: str, args: dict, content: str,
                           clip: int = 0) -> str:
    return (f"[ToolMessage tool={tool} args={canonical_args_text(args)}]\n"
            f"{clip_content(content, clip)}")


class ToolFlow:
    """Submits canonical tool streams to the server via a fusion driver."""

    def __init__(self, driver, *, enabled: bool, meter: ServingMeter,
                 params=None, clip: int = 600):
        from repro.serving.scheduler import SamplingParams
        self.driver = driver
        self.enabled = enabled
        self.meter = meter
        self.clip = clip
        self.params = params or SamplingParams(max_new_tokens=1)

    def ref_line(self, tool: str, args: dict) -> str:
        return f"[ToolRef tool={tool} key={cache_key(tool, args)[:12]}]"

    def inject(self, tool: str, args: dict, content: str, *,
               cache_hit: bool, chain_id: str = "",
               ctx=None) -> Optional[TurnRecord]:
        """Push one tool result through the serving layer; returns its
        TurnRecord (None when the flow is disabled for this config)."""
        if not self.enabled:
            return None
        prompt = canonical_tool_message(tool, args, content,
                                        clip=self.clip) + INJECT_SUFFIX
        server = self.driver.server
        t0 = time.perf_counter()
        h = self.driver.call(lambda: server.submit(prompt, self.params))
        wall = time.perf_counter() - t0
        req = h.request
        billed = 0 if cache_hit else req.prompt_tokens
        rec = TurnRecord(
            kind="inject", role=tool, chain_id=chain_id, rid=req.rid,
            status=req.status,
            error_type=type(req.error).__name__ if req.error else "",
            prompt_tokens=req.prompt_tokens, billed_tokens=billed,
            prefix_hit_tokens=req.prefix_hit_tokens,
            output_tokens=req.output_tokens, wall_s=wall,
            cache_hit=cache_hit)
        self.meter.record(rec)
        if ctx is not None:
            ctx.charge(wall)
            emit("llm", f"inject-{tool}", ctx.now() - wall, ctx.now(),
                 input_tokens=billed, output_tokens=0, cost_cents=0.0,
                 rid=req.rid, cache_hit=cache_hit,
                 prefix_hit_tokens=req.prefix_hit_tokens)
        return rec
