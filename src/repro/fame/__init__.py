"""FAME workflow runtime on the real serving stack (docs/fame.md).

``core/`` keeps the paper-faithful simulated FAME layer; this package binds
the same Planner → Actor → Evaluator state machine to the ``LLMServer`` of
PRs 1–6: persistent sessions as agent memory, canonical tool-stream injection
as cache × radix composition, co-batched handles as function fusion, and the
PR-6 fault taxonomy as Step-Function per-state Retry.
"""
from repro.fame.bindings import ChainBinding, ServingAgents
from repro.fame.fusion import CoBatchDriver, SerialDriver
from repro.fame.runtime import WorkflowServingRuntime
from repro.fame.toolflow import ToolFlow, canonical_tool_message
from repro.fame.trace import ServingMeter, TurnRecord, write_artifact

__all__ = [
    "ChainBinding", "ServingAgents", "CoBatchDriver", "SerialDriver",
    "WorkflowServingRuntime", "ToolFlow", "canonical_tool_message",
    "ServingMeter", "TurnRecord", "write_artifact",
]
