"""Function fusion on the serving side: co-batched handles (§3.3.2).

The paper consolidates MCP servers into one Lambda so co-resident functions
share a container. On the serving stack the analogue is sharing *engine
steps*: agent invocations that would run in one fused container submit their
requests together and decode in the same continuous batch
(``active_slots_per_step > 1``), instead of each invocation draining the
server alone.

Two drivers with one contract — ``call(submit_thunk) -> finished Handle`` and
``run(thunks) -> results``:

* ``SerialDriver`` — the singleton deployment. Each agent turn drains before
  the next submits; one workflow owns the engine at a time.
* ``CoBatchDriver`` — the consolidated deployment. Workflow state machines
  run on worker threads, but **all** JAX work (submit + ``server.step()``)
  happens on a single pump thread: workers hand over submit thunks and
  block until their request reaches a terminal status. Pump order drains
  every pending submit before stepping, so turns from concurrent workflows
  co-batch inside one engine iteration.

When the server runs its own background pump (``LLMServer(pump=True)``,
serving/pump.py), both drivers ride it instead of stepping: ``submit()`` is
already thread-safe (it routes through the pump's command queue, which
drains every pending submit before the next engine step — the same
co-batching guarantee CoBatchDriver's inline loop provides), so workers
just submit and block on ``Handle.wait()``. CoBatchDriver then degenerates
to plain thread fan-out with the pump doing the driving.

The same ``pumping`` check makes both drivers ride a replica fleet
(``serving/fleet.py``): a ``FleetServer`` exposes ``pumping=True``, its
``submit`` routes each chain's session to its sticky replica, and
concurrent workflow chains co-batch *per replica* — chains placed together
(prefix affinity) share engine steps there, while the fleet spreads
unrelated chains across replicas.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional


class SerialDriver:
    """Drain-per-call driver: the unfused baseline. On a pumping server it
    cannot (and must not) step — it submits and blocks on the handle."""

    def __init__(self, server):
        self.server = server

    def call(self, submit: Callable[[], Any]):
        h = submit()
        if getattr(self.server, "pumping", False):
            return h.wait()
        while not h.request.finished:
            self.server.step()
        return h

    def run(self, thunks: List[Callable[[], Any]]) -> List[Any]:
        return [t() for t in thunks]


class CoBatchDriver:
    """Single-pump-thread co-batching driver.

    JAX dispatch is not thread-safe across our program cache, so exactly
    one thread may touch the server. With a cooperative server this driver
    provides that thread itself (``run()`` pumps inline while workers hand
    over submit thunks); with ``LLMServer(pump=True)`` the server's
    background pump already owns the loop and gives the same
    submit-burst-then-step co-batching, so ``call()``/``run()`` just fan
    out workers and block on handles. ``call()`` with neither pump running
    (plain single-threaded use) degrades to SerialDriver behaviour.
    """

    def __init__(self, server):
        self.server = server
        self._cv = threading.Condition()
        self._pending: list = []        # (submit, box, event)
        self._inflight: list = []       # (handle, box, event)
        self._live_workers = 0
        self._pump_thread: Optional[threading.Thread] = None

    # ---- worker side -------------------------------------------------------
    def call(self, submit: Callable[[], Any]):
        if getattr(self.server, "pumping", False):
            return submit().wait()
        if (self._pump_thread is None
                or threading.current_thread() is self._pump_thread):
            h = submit()
            while not h.request.finished:
                self.server.step()
            return h
        box: dict = {}
        ev = threading.Event()
        with self._cv:
            self._pending.append((submit, box, ev))
            self._cv.notify()
        ev.wait()
        if "error" in box:
            raise box["error"]
        return box["handle"]

    # ---- pump side ---------------------------------------------------------
    def run(self, thunks: List[Callable[[], Any]]) -> List[Any]:
        """Run every thunk on its own worker thread while this thread pumps
        the server (or, on a pumping server, while the background pump
        drives); returns thunk results in order."""
        results: List[Any] = [None] * len(thunks)
        errors: List[Any] = [None] * len(thunks)

        def worker(i: int, thunk: Callable[[], Any]):
            try:
                results[i] = thunk()
            except BaseException as e:        # surfaced after join
                errors[i] = e
            finally:
                with self._cv:
                    self._live_workers -= 1
                    self._cv.notify()

        threads = [threading.Thread(target=worker, args=(i, t), daemon=True)
                   for i, t in enumerate(thunks)]
        if getattr(self.server, "pumping", False):
            with self._cv:
                self._live_workers = len(threads)
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            for e in errors:
                if e is not None:
                    raise e
            return results
        with self._cv:
            self._live_workers = len(threads)
        self._pump_thread = threading.current_thread()
        try:
            for th in threads:
                th.start()
            while True:
                with self._cv:
                    if (self._live_workers == 0 and not self._pending
                            and not self._inflight):
                        break
                    pending, self._pending = self._pending, []
                    if not pending and not self._inflight:
                        self._cv.wait(timeout=0.05)
                        continue
                # admit every pending submit before stepping -> co-batch
                for submit, box, ev in pending:
                    try:
                        h = submit()
                    except BaseException as e:
                        box["error"] = e
                        ev.set()
                    else:
                        self._inflight.append((h, box, ev))
                if self._inflight:
                    self.server.step()
                    still = []
                    for h, box, ev in self._inflight:
                        if h.request.finished:
                            box["handle"] = h
                            ev.set()
                        else:
                            still.append((h, box, ev))
                    self._inflight = still
            for th in threads:
                th.join()
        finally:
            self._pump_thread = None
        for e in errors:
            if e is not None:
                raise e
        return results
