"""Serving-side accounting for the FAME workflow runtime.

The simulated-clock telemetry in ``core/telemetry.py`` keeps working unchanged
(agent handlers still emit ``faas``/``mcp``/``llm`` spans); this module adds
the *real-server* side of the story: one ``TurnRecord`` per request submitted
to the ``LLMServer`` — agent turns and tool-stream injections alike — plus
stat-snapshot deltas so a benchmark cell can attribute server counters
(turn_prefix_hits, prefix_hit_tokens, …) to itself even when many cells share
one warm server.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

TERMINAL = ("completed", "failed", "timed_out", "cancelled", "shed")


@dataclasses.dataclass
class TurnRecord:
    kind: str                   # "turn" (agent call) | "inject" (tool stream)
    role: str                   # planner/actor/evaluator or tool name
    chain_id: str
    rid: int
    status: str                 # terminal RequestStatus value
    error_type: str = ""        # taxonomy class name when failed/timed_out
    prompt_tokens: int = 0      # tokens the engine saw for this request
    billed_tokens: int = 0      # client-billed input tokens (delta for
                                # session continuations, full prompt else)
    prefix_hit_tokens: int = 0  # served from radix pages / session tail
    output_tokens: int = 0
    wall_s: float = 0.0
    session_turn: int = 0       # 1-based turn index within the chain session
                                # (0 for sessionless submits)
    continuation: bool = False  # prompt extended the retained session tail
    cache_hit: Optional[bool] = None   # injections only

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL


class ServingMeter:
    """Collects TurnRecords and exposes the invariants the CI gate asserts."""

    def __init__(self, server=None):
        self.server = server
        self.records: List[TurnRecord] = []

    def record(self, rec: TurnRecord):
        self.records.append(rec)

    # ---- snapshots ---------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        return dict(self.server.stats()) if self.server else {}

    @staticmethod
    def delta(before: Dict[str, float], after: Dict[str, float]
              ) -> Dict[str, float]:
        out = {}
        for k, v in after.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                prev = before.get(k, 0)
                out[k] = v - prev if isinstance(prev, (int, float)) else v
        return out

    # ---- invariants --------------------------------------------------------
    def all_terminal(self) -> bool:
        return all(r.terminal for r in self.records)

    def turns(self, kind: str = "turn") -> List[TurnRecord]:
        return [r for r in self.records if r.kind == kind]

    def billed_in_tokens(self) -> int:
        return sum(r.billed_tokens for r in self.records)

    def continuation_turns(self) -> List[TurnRecord]:
        return [r for r in self.turns() if r.continuation]

    def tail_reuse_ok(self, slack: int = 2) -> bool:
        """Every session-continuation turn was admitted off reused state:
        the engine re-prefilled only (about) the delta, never the history.
        prefix_hit_tokens covers tail restore + radix, so a continuation
        that re-prefilled its history would show hits << prompt - billed."""
        for r in self.continuation_turns():
            if r.prefix_hit_tokens < r.prompt_tokens - r.billed_tokens - slack:
                return False
        return True

    def injection_radix_ok(self, page_size: int, suffix_slack: int = 16
                           ) -> bool:
        """Every cache-hit tool injection radix-hit its earlier stream
        instead of re-prefilling: hits reach within ~2 pages + the ack
        suffix of the full prompt (radix matches whole pages only)."""
        for r in self.records:
            if r.kind == "inject" and r.cache_hit:
                floor = r.prompt_tokens - 2 * page_size - suffix_slack
                if r.prefix_hit_tokens < floor or r.prefix_hit_tokens <= 0:
                    return False
        return True

    def summary(self) -> dict:
        turns = self.turns()
        injects = self.turns("inject")
        return {
            "turns": len(turns),
            "injections": len(injects),
            "cache_hit_injections": sum(1 for r in injects if r.cache_hit),
            "continuation_turns": len(self.continuation_turns()),
            "billed_in_tokens": self.billed_in_tokens(),
            "prompt_tokens": sum(r.prompt_tokens for r in self.records),
            "prefix_hit_tokens": sum(r.prefix_hit_tokens
                                     for r in self.records),
            "output_tokens": sum(r.output_tokens for r in self.records),
            "wall_s": sum(r.wall_s for r in self.records),
            "statuses": sorted({r.status for r in self.records}),
            "error_types": sorted({r.error_type for r in self.records
                                   if r.error_type}),
            "all_terminal": self.all_terminal(),
        }


def write_artifact(path: str, payload: dict):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    print(f"wrote {path}")
