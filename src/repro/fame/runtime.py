"""WorkflowServingRuntime: the FAME stack executed on the real serving stack.

Same assembly as ``core/runtime.FameRuntime`` — FaaS platform, object/KV
stores, agent memory, MCP cache, the Step-Functions machine — but the three
agent functions are ``fame.bindings.ServingAgents``: every agent LLM call is
a real ``LLMServer`` request driven through a fusion driver, memory configs
run on persistent sessions (tail reuse), and tool results flow through
``fame.toolflow`` (cache × radix composition). Per-state ``Retry`` policies
catch the PR-6 fault taxonomy raised by failed turns; exhausted retries
dead-letter the invocation exactly like oracle mode.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

from repro.core import config as cfg_mod
from repro.core.faas import FaaSPlatform, FunctionDef
from repro.core.kvstore import KVStore
from repro.core.llm import ScriptedOracle
from repro.core.memory import AgentMemory
from repro.core.objectstore import ObjectStore
from repro.core.runtime import SessionResult
from repro.core.telemetry import Trace, use_trace
from repro.core.toolcache import CacheManager
from repro.core.workflow import Retry, TaskState, build_react_machine
from repro.core.wrapper import WrappedServer, wrap_server
from repro.core.fusion import DeploymentPlan, plan_consolidated, plan_singleton
from repro.fame.bindings import ChainBinding, ServingAgents
from repro.fame.fusion import SerialDriver
from repro.fame.toolflow import ToolFlow
from repro.fame.trace import ServingMeter


class WorkflowServingRuntime:
    def __init__(self, *, config: cfg_mod.MemoryConfig, server,
                 driver=None, meter: Optional[ServingMeter] = None,
                 params=None, state_deadline_s: Optional[float] = None,
                 state_retry: Optional[Retry] = None,
                 fusion_mode: str = "singleton",
                 max_iterations: int = 3,
                 agent_memory_mb: int = 512,
                 stream_clip: int = 400):
        from repro.serving.scheduler import SamplingParams
        self.config = config
        self.server = server
        self.driver = driver or SerialDriver(server)
        self.meter = meter or ServingMeter(server)
        self.params = params or SamplingParams(max_new_tokens=8)
        self.state_deadline_s = state_deadline_s
        self.stream_clip = stream_clip

        self.platform = FaaSPlatform()
        self.objects = ObjectStore()
        self.kv = KVStore()
        self.memory = AgentMemory(self.kv, enabled=config.agentic_memory)
        self.cache = CacheManager(self.objects, enabled=config.mcp_caching)
        self.toolflow = ToolFlow(self.driver, enabled=config.mcp_caching,
                                 meter=self.meter, clip=stream_clip)
        self.fusion_mode = fusion_mode
        self.max_iterations = max_iterations
        self._oracles: Dict[str, ScriptedOracle] = {}
        self._default_oracle = ScriptedOracle()
        self.mcp_plan: Optional[DeploymentPlan] = None
        self._wrapped: List[WrappedServer] = []
        self._invocation_counter = itertools.count(1)
        self._chains: Dict[str, ChainBinding] = {}

        agents = ServingAgents(self)
        for name, handler in [("fame-planner", agents.planner_handler),
                              ("fame-actor", agents.actor_handler),
                              ("fame-evaluator", agents.evaluator_handler)]:
            self.platform.deploy(FunctionDef(name=name, handler=handler,
                                             memory_mb=agent_memory_mb,
                                             role="agent"))
        self.machine = build_react_machine(
            self.platform, planner_fn="fame-planner", actor_fn="fame-actor",
            evaluator_fn="fame-evaluator", max_iterations=max_iterations)
        if state_retry is not None:
            for st in self.machine.states.values():
                if isinstance(st, TaskState):
                    st.retry = state_retry

    # ---- decisions (oracle-guided; see bindings docstring) -----------------
    def decide(self, role: str, system: str, context: str) -> str:
        return self._oracles.get(role, self._default_oracle)._generate(
            system, context)

    def set_llm(self, role: str, backend):
        """Accepts the apps' ScriptedOracle builders (FameRuntime parity)."""
        self._oracles[role] = backend

    def turn_params(self):
        if self.state_deadline_s is None:
            return self.params
        return dataclasses.replace(self.params,
                                   deadline_s=self.state_deadline_s)

    # ---- chains ------------------------------------------------------------
    @property
    def persistent_chains(self) -> bool:
        """§3.2 memory persistence == session tail reuse: agentic-memory
        configs (M, M+C) keep one server session per invocation chain."""
        return self.config.agentic_memory

    def chain_for(self, payload: dict) -> ChainBinding:
        chain_id = payload["session_id"]
        chain = self._chains.get(chain_id)
        if chain is None:
            chain = ChainBinding(self, chain_id,
                                 persistent=self.persistent_chains)
            self._chains[chain_id] = chain
        return chain

    def close(self):
        for chain in self._chains.values():
            chain.close()
        self._chains.clear()

    # ---- MCP deployment (§3.3) — FameRuntime parity ------------------------
    def deploy_mcp(self, servers: Sequence,
                   sources: Optional[Dict[str, str]] = None):
        self._wrapped = [
            wrap_server(s, source=(sources or {}).get(s.name),
                        cache=self.cache, fame_runtime=self)
            for s in servers]
        if self.fusion_mode == "consolidated":
            self.mcp_plan = plan_consolidated(self._wrapped, "mcp-consolidated")
        else:
            self.mcp_plan = plan_singleton(self._wrapped)
        for fn in self.mcp_plan.functions:
            self.platform.deploy(fn)

    def mcp_function_names(self) -> List[str]:
        return [f.name for f in (self.mcp_plan.functions if self.mcp_plan
                                 else [])]

    def resolve_tool_function(self, tool: str) -> str:
        return self.mcp_plan.tool_to_function[tool]

    # ---- client sessions (multi-turn, §3.2 / Fig. 3) -----------------------
    def run_session(self, session_id: str, queries: Sequence[str],
                    t: float = 0.0, close: bool = True) -> SessionResult:
        responses, statuses, traces = [], [], []
        client_history = ""
        try:
            for query in queries:
                invocation_id = f"inv{next(self._invocation_counter):04d}"
                payload = {
                    "session_id": session_id,
                    "invocation_id": invocation_id,
                    "user_request": query,
                    "iteration": 1,
                    "max_iterations": self.max_iterations,
                    "client_history": (client_history
                                       if self.config.client_memory else ""),
                    "messages": [],
                }
                trace = Trace()
                with use_trace(trace):
                    payload, t, status = self.machine.execute(payload, t)
                response = payload.get("result_json", "")
                responses.append(response)
                statuses.append(status)
                traces.append(trace)
                if self.config.client_memory:
                    client_history += f"\n[user] {query}\n[assistant] {response}"
        finally:
            if close:
                self.close()
        return SessionResult(responses, statuses, traces, t)
