"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU temporal mixing.

RG-LRU recurrence (diagonal, real):
    r_t = sigmoid(w_r * x_t + b_r)          (recurrence gate)
    i_t = sigmoid(w_i * x_t + b_i)          (input gate)
    a_t = exp(c * softplus(Lambda) * (-r_t))  in log space, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: train/prefill uses ``jax.lax.associative_scan`` over the
linear recurrence (parallel depth log S) — the Pallas kernel
(`kernels/rglru_scan.py`) implements the time-blocked sequential variant for
deployment. Gates are diagonal (per-channel), matching the block-diagonal
spirit of the published model at equal parameter count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import ParamDef, norm_defs

C_RGLRU = 8.0


def rglru_defs(cfg):
    D = cfg.d_model
    R = cfg.rglru_dim or D
    W = cfg.conv1d_width
    return {
        "norm": norm_defs(cfg),
        "wx": ParamDef((D, R), ("embed", "rnn"), init="scaled"),
        "wy": ParamDef((D, R), ("embed", "rnn"), init="scaled"),   # gate branch
        "conv_w": ParamDef((W, R), ("conv", "rnn"), init="scaled"),
        "conv_b": ParamDef((R,), ("rnn",), init="zeros"),
        "w_rgate": ParamDef((R,), ("rnn",), init="normal"),
        "b_rgate": ParamDef((R,), ("rnn",), init="zeros"),
        "w_igate": ParamDef((R,), ("rnn",), init="normal"),
        "b_igate": ParamDef((R,), ("rnn",), init="zeros"),
        "a_param": ParamDef((R,), ("rnn",), init="normal"),        # Lambda
        # wo contracts over R: own logical axis so serve replicates it
        # (bit-exact — see distributed/sharding.py) while train keeps TP
        "wo": ParamDef((R, D), ("rnn_in", "embed"), init="scaled"),
    }


def causal_conv1d(x, w, b, state=None, length=None):
    """Depthwise causal conv. x [B,S,R], w [W,R]; state [B,W-1,R] or None.

    ``length`` (traced scalar, optional): number of valid leading positions
    when ``x`` is right-padded (bucketed prefill) — the returned state then
    holds the inputs at positions [length-W+1, length) rather than the padded
    tail. Returns (y [B,S,R], new_state [B,W-1,R]).
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xs = jnp.concatenate([state, x], axis=1)          # [B, S+W-1, R]
    y = sum(xs[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    if W <= 1:
        new_state = state
    elif length is None:
        new_state = xs[:, -(W - 1):]
    else:
        # xs index j holds the input at position j - (W-1); the state for a
        # sequence ending at `length` is positions [length-W+1, length).
        new_state = jax.lax.dynamic_slice_in_dim(xs, length, W - 1, axis=1)
    return y.astype(x.dtype), new_state


def _gates(p, x):
    """log a_t [.., R] (f32) and gated input beta*i*x (f32)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["w_rgate"].astype(jnp.float32) + p["b_rgate"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf * p["w_igate"].astype(jnp.float32) + p["b_igate"].astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xf


def rglru_scan(p, x, h0=None, mask=None, all_states: bool = False):
    """Linear recurrence over [B,S,R] via associative scan. Returns (y, h_S),
    or (y, hh [B,S,R] f32 — the state after EVERY position) when
    ``all_states`` — the speculative verify step keeps all of them so the
    accept step can rewind to any accepted prefix with a gather.

    ``mask`` [B,S] bool: padded positions become identity steps (a=1, input=0)
    so the final state equals the state after the last *valid* position.
    """
    a, bx = _gates(p, x)                       # [B,S,R] f32
    if mask is not None:
        a = jnp.where(mask[..., None], a, 1.0)
        bx = jnp.where(mask[..., None], bx, 0.0)
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def op(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, ar * bl + br

    aa, hh = jax.lax.associative_scan(op, (a, bx), axis=1)
    return hh.astype(x.dtype), (hh if all_states else hh[:, -1])


def rglru_step(p, x, h):
    """Single decode step. x [B,1,R], h [B,R] f32 -> (y [B,1,R], h')."""
    a, bx = _gates(p, x[:, 0])
    h_new = a * h.astype(jnp.float32) + bx
    return h_new[:, None].astype(x.dtype), h_new


def apply_recurrent_mixer(p, x, cfg, *, cache=None, mode="full", length=None,
                          mask=None):
    """Full Griffin temporal-mixing branch (pre-norm handled by caller).

    x [B,S,D] -> (y [B,S,D], new_cache) with cache {"h": [B,R] f32,
    "conv": [B,W-1,R]}. ``length``/``mask`` mark the valid prefix when the
    prompt is right-padded to a prefill bucket (identity steps — a=1,
    input=0 — past the valid prefix). ``mode="verify"`` returns a staged
    record instead of a cache: per-position states the speculative accept
    step rewinds with a gather (``verify_commit``) — batched across rows,
    no replay forward.
    """
    u = jnp.einsum("bsd,dr->bsr", x, p["wx"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["wy"]))
    # extend / verify (prefill or draft continuation) resume conv +
    # recurrence state from the cache instead of zeros
    prev_conv = cache["conv"] if mode in ("extend", "verify") else None
    h0 = cache["h"] if mode in ("extend", "verify") else None
    if mode == "decode":
        c, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"], cache["conv"])
        y, h = rglru_step(p, c, cache["h"])
    elif mode == "verify":
        # batched speculative verify: per-row draft chunks at per-row valid
        # lengths (``mask``). Nothing is committed here — the staged record
        # holds the state after EVERY draft position plus the raw conv input
        # stream, and ``verify_commit`` gathers the state at each row's
        # accepted length once the accept step has chosen it (the batched
        # replacement for the old per-slot snapshot+replay rollback).
        c, _ = causal_conv1d(u, p["conv_w"], p["conv_b"], prev_conv)
        xs = jnp.concatenate([prev_conv, u], axis=1)      # [B, S+W-1, R]
        y, hh = rglru_scan(p, c, h0=h0, mask=mask, all_states=True)
        yg = constrain(y * gate, "batch", None, "rnn_act")
        out = jnp.einsum("bsr,rd->bsd", yg, p["wo"])
        return out, {"hh": hh, "xs": xs, "h0": cache["h"]}
    elif cfg.use_pallas:
        from repro.kernels import rglru_scan as _krg
        c, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"], prev_conv,
                                      length=length)
        a, bx = _gates(p, c)
        if mask is not None:
            a = jnp.where(mask[..., None], a, 1.0)
            bx = jnp.where(mask[..., None], bx, 0.0)
        if h0 is not None:
            bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        y, h = _krg.rglru_scan(a.astype(c.dtype), bx.astype(c.dtype))
        y = y.astype(c.dtype)
    else:
        c, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"], prev_conv,
                                      length=length)
        y, h = rglru_scan(p, c, h0=h0, mask=mask)
    # "rnn_act": serve gathers the R-sharded mixed output here so the wo
    # contraction is never split across devices (train/decode: no-op)
    yg = constrain(y * gate, "batch", None, "rnn_act")
    out = jnp.einsum("bsr,rd->bsd", yg, p["wo"])
    return out, {"h": h, "conv": conv_state}


def verify_commit(staged, ns, valid):
    """Rewind one RG-LRU layer's verify record to each row's accepted length.

    staged: ``{"hh" [B,S,R] f32, "xs" [B,S+W-1,R], "h0" [B,R]}`` from
    ``apply_recurrent_mixer(mode="verify")``; ns [B] = accepted inputs per
    row (1..S); valid [B] = rows that took part in this verify step (the
    rest keep their pre-verify state untouched). Returns the committed
    ``{"h", "conv"}`` cache — state exactly after the first ``ns`` inputs,
    with no replay forward.
    """
    hh, xs, h0 = staged["hh"], staged["xs"], staged["h0"]
    B, S, R = hh.shape
    W1 = xs.shape[1] - S                                   # conv width - 1
    idx = jnp.clip(ns - 1, 0, S - 1)
    h = jnp.take_along_axis(hh, idx[:, None, None], axis=1)[:, 0]
    h = jnp.where(valid[:, None], h, h0)
    # conv window after n inputs = stream positions [n-W+1, n) = xs[n:n+W-1]
    n_eff = jnp.where(valid, jnp.clip(ns, 0, S), 0)        # 0 -> old window
    conv = jax.vmap(
        lambda row, n: jax.lax.dynamic_slice_in_dim(row, n, W1, axis=0)
    )(xs, n_eff)
    return {"h": h, "conv": conv}
