"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar).

TPU adaptation (DESIGN.md §3): the GPU reference implements mLSTM as a fused
step-recurrent CUDA kernel; here we use the *chunkwise-parallel* formulation —
intra-chunk attention-like einsums (MXU-friendly) + an inter-chunk state scan
— mathematically equivalent under the standard max-stabilizer. The naive
sequential recurrence lives in ``kernels/ref.py`` as the oracle; tests check
chunkwise == sequential. sLSTM's state nonlinearity is inherently sequential
(per the xLSTM paper), so it stays a ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, norm_defs
from repro.models.rglru import causal_conv1d


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def mlstm_defs(cfg):
    D = cfg.d_model
    F2 = int(cfg.mlstm_proj_factor * D)
    H = cfg.num_heads
    W = cfg.conv1d_width
    return {
        "norm": norm_defs(cfg),
        "w_up": ParamDef((D, F2), ("embed", "inner"), init="scaled"),
        "w_gate": ParamDef((D, F2), ("embed", "inner"), init="scaled"),
        "conv_w": ParamDef((W, F2), ("conv", "inner"), init="scaled"),
        "conv_b": ParamDef((F2,), ("inner",), init="zeros"),
        "wq": ParamDef((F2, F2), ("inner", "inner_out"), init="scaled"),
        "wk": ParamDef((F2, F2), ("inner", "inner_out"), init="scaled"),
        "wv": ParamDef((F2, F2), ("inner", "inner_out"), init="scaled"),
        "w_ig": ParamDef((F2, H), ("inner", None), init="scaled"),
        "b_ig": ParamDef((H,), (None,), init="zeros"),
        "w_fg": ParamDef((F2, H), ("inner", None), init="scaled"),
        "b_fg": ParamDef((H,), (None,), init="ones"),   # bias toward remembering
        "out_norm": ParamDef((F2,), ("inner",), init="ones"),
        "w_down": ParamDef((F2, D), ("inner", "embed"), init="scaled"),
    }


def slstm_defs(cfg):
    D = cfg.d_model
    H = cfg.slstm_heads
    hd = D // H
    return {
        "norm": norm_defs(cfg),
        # "slstm_inner" is replicated (§Perf): the sequential scan would
        # otherwise psum [B, D] across `model` EVERY timestep (32768 steps!)
        # because heads (hd=256) straddle 16 model shards of 64 channels.
        "w_gates": ParamDef((D, 4, D), ("embed", None, "slstm_inner"), init="scaled"),
        "r_gates": ParamDef((H, 4, hd, hd), (None, None, None, None), init="scaled"),
        "b_gates": ParamDef((4, D), (None, "slstm_inner"), init="zeros"),
        "out_norm": ParamDef((D,), ("slstm_inner",), init="ones"),
        "wo": ParamDef((D, D), ("slstm_inner", "embed"), init="scaled"),
    }


# ---------------------------------------------------------------------------
# mLSTM chunkwise-parallel cell
# ---------------------------------------------------------------------------


def mlstm_chunkwise(q, k, v, ig, fg, state=None, *, chunk: int = 256):
    """Stabilized chunkwise mLSTM.

    q,k,v: [B, S, H, hd]; ig,fg: [B, S, H] (pre-activations, log-space).
    state: (C [B,H,hd,hd], n [B,H,hd], m [B,H]) f32 or None.
    Returns (h [B,S,H,hd], state').
    """
    B, S, H, hd = q.shape
    L = min(chunk, S)
    Sp = -(-S // L) * L
    if Sp != S:
        # pad so padded positions contribute nothing: i-gate -> -inf (zero
        # write weight), f-gate -> +large (zero extra decay)
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, pad) for a in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, Sp - S), (0, 0)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, Sp - S), (0, 0)), constant_values=30.0)
    S_out = S
    S = Sp
    nc = S // L
    scale = hd ** -0.5

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nc, L, *x.shape[2:]), 1, 0)

    qc, kc, vc = to_chunks(q * scale), to_chunks(k), to_chunks(v)
    igc, fgc = to_chunks(ig.astype(jnp.float32)), to_chunks(fg.astype(jnp.float32))

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, xs):
        C, n, m = carry
        qj, kj, vj, ij, fj = xs                     # [B,L,H,*]
        logf = jax.nn.log_sigmoid(fj)               # [B,L,H]
        F = jnp.cumsum(logf, axis=1)                # decay chunk-start..j inclusive
        FL = F[:, -1]                               # [B,H]
        # intra-chunk pair weights: D_ji = F_j - F_i + i_i   (i <= j)
        #   (decay from i+1..j) = F_j - F_i
        logD = F[:, :, None, :] - F[:, None, :, :] + ij[:, None, :, :]  # [B,L(j),L(i),H]
        tri = jnp.tril(jnp.ones((L, L), bool))
        logD = jnp.where(tri[None, :, :, None], logD, -1e30)
        m_intra = jnp.max(logD, axis=2)             # [B,L,H]
        m_inter = F + m[:, None, :]                 # [B,L,H]
        mj = jnp.maximum(m_inter, m_intra)
        d = jnp.exp(logD - mj[:, :, None, :])       # [B,L,L,H]
        inter = jnp.exp(m_inter - mj)               # [B,L,H]

        s = jnp.einsum("blhd,bmhd->blmh", qj, kj,
                       preferred_element_type=jnp.float32)      # [B,L(j),L(i),H]
        w = s * d
        h_intra = jnp.einsum("blmh,bmhd->blhd", w.astype(vj.dtype), vj,
                             preferred_element_type=jnp.float32)
        h_inter = jnp.einsum("blhd,bhde->blhe", qj.astype(jnp.float32), C)
        h_num = h_inter * inter[..., None] + h_intra
        n_intra = jnp.einsum("blmh,bmhd->blhd", d, kj.astype(jnp.float32))
        n_j = n[:, None] * inter[..., None] + n_intra                       # [B,L,H,hd]
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("blhd,blhd->blh", qj.astype(jnp.float32), n_j)),
            jnp.exp(-mj))
        h = h_num / denom[..., None]

        # ---- state to end of chunk -----------------------------------------
        m_next = jnp.maximum(FL + m, jnp.max(FL[:, None] - F + ij, axis=1))
        sc = jnp.exp(FL[:, None] - F + ij - m_next[:, None])    # [B,L,H]
        C_next = (C * jnp.exp(FL + m - m_next)[..., None, None]
                  + jnp.einsum("blh,blhd,blhe->bhde", sc,
                               kj.astype(jnp.float32), vj.astype(jnp.float32)))
        n_next = (n * jnp.exp(FL + m - m_next)[..., None]
                  + jnp.einsum("blh,blhd->bhd", sc, kj.astype(jnp.float32)))
        return (C_next, n_next, m_next), h.astype(q.dtype)

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, igc, fgc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)[:, :S_out]
    return h, (C, n, m)


def mlstm_step(q, k, v, ig, fg, state):
    """Single decode step. q,k,v [B,1,H,hd]; ig,fg [B,1,H]."""
    C, n, m = state
    q1, k1, v1 = (x[:, 0].astype(jnp.float32) for x in (q, k, v))
    scale = q.shape[-1] ** -0.5
    logf = jax.nn.log_sigmoid(fg[:, 0].astype(jnp.float32))
    i1 = ig[:, 0].astype(jnp.float32)
    m_new = jnp.maximum(logf + m, i1)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(i1 - m_new)
    C_new = C * fp[..., None, None] + ip[..., None, None] * (k1[..., :, None] * v1[..., None, :])
    n_new = n * fp[..., None] + ip[..., None] * k1
    qs = q1 * scale
    h_num = jnp.einsum("bhd,bhde->bhe", qs, C_new)
    denom = jnp.maximum(jnp.abs(jnp.sum(qs * n_new, axis=-1)), jnp.exp(-m_new))
    h = (h_num / denom[..., None])[:, None]
    return h.astype(q.dtype), (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# Block assembly
# ---------------------------------------------------------------------------


def _heads(x, H):
    B, S, F2 = x.shape
    return x.reshape(B, S, H, F2 // H)


def _group_norm_heads(x, scale, eps=1e-6):
    """Per-head RMS norm with a flat [F2] learned scale (xLSTM multi-head norm)."""
    B, S, H, hd = x.shape
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = (xf * jax.lax.rsqrt(ms + eps)).reshape(B, S, H * hd)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def apply_mlstm(p, x, cfg, *, cache=None, mode="full", length=None, mask=None):
    """x [B,S,D] -> (y, new_cache). cache: {"state": (C,n,m), "conv": [B,W-1,F2]}.

    ``length``/``mask`` mark the valid prefix under right-padded (bucketed)
    prefill: padded positions get i-gate -> -inf / f-gate -> +large (the same
    trick the chunkwise cell uses for its internal padding), so they neither
    write to nor decay the (C, n, m) state. ``mode="verify"`` returns a
    staged record of per-position states instead of a cache; the
    speculative accept step rewinds the matrix memory to each row's
    accepted length with a gather (``mlstm_verify_commit``) — batched, no
    replay forward.
    """
    H = cfg.num_heads
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    z = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    conv_state = cache["conv"] if mode in ("decode", "extend", "verify") else None
    # verify: per-row lengths live in ``mask``; the committed conv window is
    # gathered from the staged input stream, not the scalar-length slice
    c, new_conv = causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state,
                                length=None if mode == "verify" else length)
    c = jax.nn.silu(c)
    q = _heads(jnp.einsum("bsf,fg->bsg", c, p["wq"]), H)
    k = _heads(jnp.einsum("bsf,fg->bsg", c, p["wk"]), H)
    v = _heads(jnp.einsum("bsf,fg->bsg", u, p["wv"]), H)
    ig = jnp.einsum("bsf,fh->bsh", u, p["w_ig"]) + p["b_ig"]
    fg = jnp.einsum("bsf,fh->bsh", u, p["w_fg"]) + p["b_fg"]
    if mask is not None and mode != "decode":
        ig = jnp.where(mask[..., None], ig, -1e30)
        fg = jnp.where(mask[..., None], fg, 30.0)
    if mode == "verify":
        # batched speculative verify: step the exact decode recurrence over
        # the (tiny) draft chunk, stacking the (C, n, m) state after every
        # position so ``mlstm_verify_commit`` can rewind to any accepted
        # length with a gather — no per-slot snapshot+replay. Masked rows
        # (i-gate -1e30 / f-gate +30) step as identities, so padded draft
        # tails neither write to nor decay the matrix memory.
        def step(st, xs_t):
            qt, kt, vt, it, ft = xs_t
            h_t, st2 = mlstm_step(qt[:, None], kt[:, None], vt[:, None],
                                  it[:, None], ft[:, None], st)
            return st2, (h_t[:, 0], st2)
        xs_t = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, ig, fg))
        _, (hs, states) = jax.lax.scan(step, cache["state"], xs_t)
        h = jnp.moveaxis(hs, 0, 1)
        h = _group_norm_heads(h, p["out_norm"])
        y = jnp.einsum("bsf,fd->bsd", h * jax.nn.silu(z), p["w_down"])
        staged = {"states": tuple(jnp.moveaxis(s, 0, 1) for s in states),
                  "state0": cache["state"],
                  "xs": jnp.concatenate([cache["conv"], u], axis=1)}
        return y, staged
    if mode == "decode":
        h, state = mlstm_step(q, k, v, ig, fg, cache["state"])
    elif mode == "extend":
        # chunked-prefill continuation: resume (C, n, m) from the cache (the
        # Pallas chunk kernel has no initial-state input, so extend always
        # takes the XLA chunkwise path)
        h, state = mlstm_chunkwise(q, k, v, ig, fg, cache["state"],
                                   chunk=cfg.mlstm_chunk)
    elif cfg.use_pallas:
        from repro.kernels import mlstm_chunk as _kmc
        h = _kmc.mlstm_chunk(q, k, v, ig, fg, chunk=cfg.mlstm_chunk)
        _, state = mlstm_chunkwise(q, k, v, ig, fg, chunk=cfg.mlstm_chunk)
    else:
        h, state = mlstm_chunkwise(q, k, v, ig, fg, chunk=cfg.mlstm_chunk)
    h = _group_norm_heads(h, p["out_norm"])
    y = jnp.einsum("bsf,fd->bsd", h * jax.nn.silu(z), p["w_down"])
    return y, {"state": state, "conv": new_conv}


def slstm_scan(p, x, cfg, state=None, mask=None, all_states: bool = False):
    """Sequential sLSTM over [B,S,D]. state: (c,n,h,m) each [B,D] f32.

    ``mask`` [B,S] bool: padded timesteps carry the state through unchanged.
    ``all_states``: additionally return the state after every position
    (each [B,S,D] f32) — the verify step's accept-rewind record.
    """
    B, S, D = x.shape
    H = cfg.slstm_heads
    hd = D // H
    gates_x = jnp.einsum("bsd,dge->bsge", x, p["w_gates"]) + p["b_gates"]  # [B,S,4,D]
    if state is None:
        zeros = jnp.zeros((B, D), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((B, D), -1e30, jnp.float32))
    if mask is None:
        mask = jnp.ones((B, S), bool)

    def step(carry, xs):
        gx, mt = xs                                  # [B,4,D], [B]
        c, n, h, m = carry
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("bhd,hgde->bhge", hh.astype(x.dtype), p["r_gates"])
        g = gx.astype(jnp.float32) + rec.transpose(0, 2, 1, 3).reshape(B, 4, D).astype(jnp.float32)
        gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        m_new = jnp.maximum(gf + m, gi)
        fp = jnp.exp(gf + m - m_new)
        ip = jnp.exp(gi - m_new)
        c_new = fp * c + ip * jnp.tanh(gz)
        n_new = fp * n + ip
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        keep = mt[:, None]
        new = tuple(jnp.where(keep, a, b)
                    for a, b in zip((c_new, n_new, h_new, m_new), (c, n, h, m)))
        y = h_new.astype(x.dtype)
        return new, ((y, new) if all_states else y)

    gates_t = jnp.moveaxis(gates_x, 1, 0)           # [S,B,4,D]
    mask_t = jnp.moveaxis(mask, 1, 0)               # [S,B]
    new_state, ys = jax.lax.scan(step, state, (gates_t, mask_t))
    if all_states:
        hs, states = ys
        return jnp.moveaxis(hs, 0, 1), tuple(jnp.moveaxis(s, 0, 1)
                                             for s in states)
    return jnp.moveaxis(ys, 0, 1), new_state


def apply_slstm(p, x, cfg, *, cache=None, mode="full", length=None, mask=None):
    state = cache["state"] if mode in ("decode", "extend", "verify") else None
    h, new_state = slstm_scan(p, x, cfg, state,
                              mask=mask if mode != "decode" else None,
                              all_states=mode == "verify")
    hf = h.astype(jnp.float32)
    ms = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    h = ((hf * jax.lax.rsqrt(ms + 1e-6)) * p["out_norm"].astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", h, p["wo"])
    if mode == "verify":
        # new_state is the per-position state stack; commit gathers at the
        # accepted length (slstm_verify_commit)
        return y, {"states": new_state, "state0": cache["state"]}
    return y, {"state": new_state}


# ---------------------------------------------------------------------------
# Speculative-verify commit: rewind the staged per-position states to each
# row's accepted length (batched; replaces per-slot snapshot+replay)
# ---------------------------------------------------------------------------


def _gather_states(states, state0, ns, valid):
    """Pick state after input ``ns[b]`` per row from per-position stacks
    (each [B, S, ...]); invalid rows keep their pre-verify state."""
    S = jax.tree.leaves(states)[0].shape[1]
    idx = jnp.clip(ns - 1, 0, S - 1)

    def pick(stack, old):
        ix = idx.reshape((-1,) + (1,) * (stack.ndim - 1))
        sel = jnp.take_along_axis(stack, ix, axis=1)[:, 0]
        v = valid.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(v, sel, old)

    return jax.tree.map(pick, states, state0)


def mlstm_verify_commit(staged, ns, valid):
    """staged: {"states": (C,n,m) each [B,S,...], "state0", "xs"} from
    ``apply_mlstm(mode="verify")``. Returns the committed
    {"state", "conv"} cache at each row's accepted length."""
    state = _gather_states(staged["states"], staged["state0"], ns, valid)
    xs = staged["xs"]                                       # [B, S+W-1, F2]
    S = staged["states"][2].shape[1]
    W1 = xs.shape[1] - S
    n_eff = jnp.where(valid, jnp.clip(ns, 0, S), 0)
    conv = jax.vmap(
        lambda row, n: jax.lax.dynamic_slice_in_dim(row, n, W1, axis=0)
    )(xs, n_eff)
    return {"state": state, "conv": conv}


def slstm_verify_commit(staged, ns, valid):
    """staged: {"states": (c,n,h,m) each [B,S,D], "state0"} from
    ``apply_slstm(mode="verify")``."""
    return {"state": _gather_states(staged["states"], staged["state0"],
                                    ns, valid)}
