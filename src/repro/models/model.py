"""Thin façade over the functional model: init / specs / axes / entry points."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import init_params, logical_axes, param_specs


class Model:
    """Stateless model handle for one ModelConfig."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.defs = tfm.model_defs(cfg)

    # ---- params ------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return init_params(self.defs, key, self.cfg.param_dtype)

    def param_specs(self):
        return param_specs(self.defs, self.cfg.param_dtype)

    def param_axes(self):
        return logical_axes(self.defs)

    def param_count(self) -> int:
        return sum(int(jnp.size(jnp.zeros(s.shape, jnp.int8)) * 0 + 1) *
                   int(functools.reduce(lambda a, b: a * b, s.shape, 1))
                   for s in jax.tree.leaves(self.param_specs()))

    # ---- caches ------------------------------------------------------------
    def cache_spec(self, batch: int, capacity: int):
        return tfm.cache_spec(self.cfg, batch, capacity)

    def init_cache(self, batch: int, capacity: int):
        return tfm.init_cache(self.cfg, batch, capacity)

    # ---- entry points --------------------------------------------------------
    def loss(self, params, batch):
        return tfm.train_loss(params, batch, self.cfg)

    def prefill(self, params, batch, cache, **kw):
        return tfm.prefill(params, batch, self.cfg, cache, **kw)

    def decode_step(self, params, batch, cache, cache_len, **kw):
        return tfm.decode_step(params, batch, self.cfg, cache, cache_len, **kw)

    def extend(self, params, batch, cache, cache_len, **kw):
        """Prefill continuation against a partially-filled cache (chunked
        prefill / shared-prefix suffix prefill / speculative replay after a
        partial draft accept). See transformer.extend."""
        return tfm.extend(params, batch, self.cfg, cache, cache_len, **kw)

    def verify(self, params, batch, cache, cache_lens, **kw):
        """Speculative-decode verify: score all draft positions in one
        forward, per-row cache lengths. See transformer.verify."""
        return tfm.verify(params, batch, self.cfg, cache, cache_lens, **kw)

    def verify_commit(self, staged, cache_lens, ns, lens):
        """Resolve a verify call's staged record to the committed cache at
        each row's accepted length (batched accept-rewind for stateful
        blocks; identity for linear full attention). See
        transformer.verify_commit."""
        return tfm.verify_commit(self.cfg, staged, cache_lens, ns, lens)

    # ---- input construction ------------------------------------------------
    def make_batch(self, tokens_or_frames, *, labels=None, positions=None, start=0):
        cfg = self.cfg
        key = "frames" if cfg.modality == "audio_frames" else "tokens"
        arr = tokens_or_frames
        B, S = arr.shape[0], arr.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(start + jnp.arange(S)[None, :], (B, S))
        batch = {key: arr, "positions": positions}
        if labels is not None:
            batch["labels"] = labels
        return batch
