"""Parameter definitions and basic layers (norms, MLP, rotary, positions).

Single source of truth: each parameter is a ``ParamDef(shape, axes, init)``;
``init_params`` / ``param_specs`` / ``logical_axes`` all derive from the same
def-tree, so shapes and shardings can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

# ---------------------------------------------------------------------------
# ParamDef machinery
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names, len == ndim
    init: str = "normal"                      # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: Optional[str] = None               # override param dtype (e.g. f32 states)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(d: ParamDef, key, dtype) -> jax.Array:
    dt = jnp.dtype(d.dtype or dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "scaled":  # fan-in scaled normal
        fan_in = d.shape[0] if len(d.shape) == 1 else math.prod(d.shape[:-1])
        return (jax.random.normal(key, d.shape, jnp.float32) / math.sqrt(max(fan_in, 1))).astype(dt)
    return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dt)


def init_params(defs, key, dtype="bfloat16"):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_specs(defs, dtype="bfloat16"):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or dtype)),
        defs, is_leaf=is_def)


def logical_axes(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacking (scan) dimension to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale, d.dtype),
        defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_defs(cfg, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    d = {"scale": ParamDef((dim,), ("norm",), init="ones")}
    if cfg.norm_type == "layernorm":
        d["bias"] = ParamDef((dim,), ("norm",), init="zeros")
    return d


def apply_norm(p, x, cfg, eps: Optional[float] = None):
    eps = eps or cfg.norm_eps
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x, eps=1e-6):
    """Per-head RMS norm (chameleon qk-norm), no learned scale."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_defs(cfg):
    D, F = cfg.d_model, cfg.d_ff
    d = {
        "wi": ParamDef((D, F), ("embed", "mlp"), init="scaled"),
        # the contraction side of the down-projection gets its own logical
        # axis: train/decode shard it over "model" (Megatron layout), serve
        # replicates it so the contraction is never split (bit-exact)
        "wo": ParamDef((F, D), ("mlp_in", "embed"), init="scaled"),
    }
    if cfg.gated_mlp:
        d["wg"] = ParamDef((D, F), ("embed", "mlp"), init="scaled")
    if cfg.mlp_bias:
        d["bi"] = ParamDef((F,), ("mlp",), init="zeros")
        d["bo"] = ParamDef((cfg.d_model,), ("embed",), init="zeros")
    return d


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def apply_mlp(p, x, cfg):
    pet = jnp.bfloat16 if getattr(cfg, "bf16_reduce", False) else None
    h = jnp.einsum("bsd,df->bsf", x, p["wi"], preferred_element_type=pet)
    if cfg.mlp_bias:
        h = h + p["bi"]
    if cfg.gated_mlp:
        h = activation(h, cfg.act) * jnp.einsum("bsd,df->bsf", x, p["wg"])
    else:
        h = activation(h, cfg.act)
    h = constrain(h, "batch", None, "mlp_act")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"], preferred_element_type=pet)
    if cfg.mlp_bias:
        y = y + p["bo"]
    return constrain(y, "batch", None, None)


# ---------------------------------------------------------------------------
# Positions: rotary + sinusoidal
# ---------------------------------------------------------------------------


def rotary_embed(x, positions, theta: float, rotary_pct: float = 1.0):
    """Apply RoPE to ``x[..., S, H, hd]`` given ``positions [B, S]``.

    ``rotary_pct < 1`` rotates only the leading fraction of the head dim
    (ChatGLM-style 2d rope); the remainder passes through untouched.
    """
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # [B, S, half]
    cos = jnp.cos(ang)[..., None, :]                                 # [B, S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_pos(positions, d_model: int, dtype):
    """Classic transformer sinusoidal positional encoding, [B, S, D]."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(cfg):
    d = {"embed": {"table": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"))}}
    if not cfg.tie_embeddings:
        d["unembed"] = {"table": ParamDef((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), init="scaled")}
    return d


def embed_tokens(params, tokens, cfg):
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = constrain(x, "batch", None, None)
    if cfg.name.startswith("gemma") or cfg.family == "hybrid":
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma-family scaling
    return x


def unembed(params, x, cfg):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["table"],
                            preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return constrain(logits, "batch", None, "vocab")
