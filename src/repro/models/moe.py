"""Mixture-of-Experts FFN: top-k token-choice routing, einsum dispatch.

TPU adaptation (see DESIGN.md §3): dense Mesh-TF-style dispatch with a capacity
factor — static shapes, MXU-aligned einsums, no sorting / dynamic gather.
Tokens are processed in groups of ``cfg.moe_group_size`` so the one-hot
dispatch tensor stays bounded: [N, G, E, C] with C = ceil(G*k/E * cf).

Router aux losses (load-balancing + z-loss) are returned for the train loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import ParamDef, activation


def moe_defs(cfg):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    # the per-expert hidden dim gets its own logical axis ("moe_mlp"): it is
    # the contraction side of the expert down-projection, and mapping it
    # independently of the dense-MLP "mlp" axis lets serve shard the expert
    # index over "model" (expert parallelism) while keeping F replicated —
    # the same axis on both would collide in one PartitionSpec
    d = {
        "router": ParamDef((D, E), ("embed", None), init="scaled"),
        "wi": ParamDef((E, D, F), ("experts", "moe_embed", "moe_mlp"), init="scaled"),
        "wo": ParamDef((E, F, D), ("experts", "moe_mlp", "moe_embed"), init="scaled"),
    }
    if cfg.gated_mlp:
        d["wg"] = ParamDef((E, D, F), ("experts", "moe_embed", "moe_mlp"), init="scaled")
    return d


def capacity(cfg, group: int) -> int:
    c = int(group * cfg.experts_per_token / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def route(router_w, x, cfg):
    """x [N,G,D] -> dispatch [N,G,E,C] bf16, combine [N,G,E,C] f32, aux losses."""
    E, k = cfg.num_experts, cfg.experts_per_token
    G = x.shape[1]
    C = capacity(cfg, G)
    logits = jnp.einsum("ngd,de->nge", x, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- aux losses ------------------------------------------------------
    # load balance: mean prob per expert vs fraction of tokens routed there
    top1 = jnp.argmax(probs, axis=-1)
    frac_routed = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
    frac_prob = jnp.mean(probs, axis=(0, 1))
    aux_loss = cfg.aux_loss_weight * E * jnp.sum(frac_routed * frac_prob)
    z_loss = cfg.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- top-k dispatch with capacity -------------------------------------
    dispatch = jnp.zeros((x.shape[0], G, E, C), jnp.bfloat16)
    combine = jnp.zeros((x.shape[0], G, E, C), jnp.float32)
    p_rem = probs
    prev_count = jnp.zeros((x.shape[0], 1, E), jnp.int32)
    gate_sum = jnp.zeros(probs.shape[:2] + (1,), jnp.float32)
    onehots = []
    for _ in range(k):
        choice = jnp.argmax(p_rem, axis=-1)                     # [N,G]
        oh = jax.nn.one_hot(choice, E, dtype=jnp.float32)        # [N,G,E]
        gate = jnp.sum(p_rem * oh, axis=-1, keepdims=True)       # [N,G,1]
        pos = jnp.cumsum(oh, axis=1) - oh + prev_count           # slot within expert
        keep = (pos < C) * oh                                    # [N,G,E]
        slot = jnp.sum(pos * oh, axis=-1).astype(jnp.int32)      # [N,G]
        slot_oh = jax.nn.one_hot(jnp.clip(slot, 0, C - 1), C, dtype=jnp.float32)
        d = keep[..., None] * slot_oh[:, :, None, :]             # [N,G,E,C]
        dispatch = dispatch + d.astype(jnp.bfloat16)
        combine = combine + d * gate[..., None]
        gate_sum = gate_sum + gate * jnp.sum(keep, axis=-1, keepdims=True)
        prev_count = prev_count + jnp.sum(oh, axis=1, keepdims=True).astype(jnp.int32)
        p_rem = p_rem * (1.0 - oh)
        onehots.append(oh)
    combine = combine / jnp.maximum(gate_sum[..., None], 1e-9)   # renormalize top-k
    return dispatch, combine.astype(jnp.bfloat16), aux_loss + z_loss


def apply_moe(p, x, cfg):
    """x [B,S,D] -> [B,S,D], aux_loss scalar."""
    B, S, D = x.shape
    T = B * S
    G = min(cfg.moe_group_size, T)
    Tp = -(-T // G) * G                       # pad to a group multiple
    xf = x.reshape(T, D)
    if Tp != T:
        xf = jnp.pad(xf, ((0, Tp - T), (0, 0)))
    N = Tp // G
    xg = constrain(xf.reshape(N, G, D), "batch", None, None)
    dispatch, combine, aux = route(p["router"], xg, cfg)
    dispatch = constrain(dispatch, "batch", None, None, None)
    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg.astype(jnp.bfloat16))
    # Expert parallelism (§Perf): compute the dispatch batch-sharded, THEN
    # reshard the group axis -> expert axis. The two-step constraint makes
    # the partitioner emit an activation-sized all-to-all instead of
    # gathering tokens (or expert weights) — the DeepSpeed-MoE/Switch layout.
    xe = constrain(xe, "batch", None, None, None)
    xe = constrain(xe, "moe_tokens", "experts_run", None, None)
    pet = jnp.bfloat16 if cfg.bf16_reduce else None
    h = jnp.einsum("necd,edf->necf", xe, p["wi"], preferred_element_type=pet)
    if cfg.gated_mlp:
        h = activation(h, cfg.act) * jnp.einsum("necd,edf->necf", xe, p["wg"],
                                                preferred_element_type=pet)
    else:
        h = activation(h, cfg.act)
    h = constrain(h, "moe_tokens", "experts_run", None, "moe_mlp")
    ye = jnp.einsum("necf,efd->necd", h, p["wo"], preferred_element_type=pet)
    ye = constrain(ye, "moe_tokens", "experts_run", None, None)
    ye = constrain(ye, "batch", None, None, None)
    y = jnp.einsum("necd,ngec->ngd", ye, combine)
    y = y.reshape(Tp, D)[:T]
    return constrain(y.reshape(B, S, D).astype(x.dtype), "batch", None, None), aux
