"""Block assembly + scan-over-layers stack + train/prefill/decode entry points.

The layer stack is ``cfg.pattern`` repeated ``cfg.num_scan_groups`` times (a
single ``lax.scan`` over stacked params — O(1) HLO size in depth) plus an
explicit tail for patterns that don't divide ``num_layers`` (recurrentgemma:
38 = 12×(R,R,A) + (R,R)).

Caches mirror the param structure: ``{"scan": {"sub<i>": stacked}, "tail<j>":
...}`` plus a scalar ``cache_len`` carried by the caller.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (apply_mlp, apply_norm, embed_defs,
                                 embed_tokens, init_params, logical_axes,
                                 mlp_defs, norm_defs, param_specs,
                                 sinusoidal_pos, stack_defs, unembed)

# ---------------------------------------------------------------------------
# Per-block param defs
# ---------------------------------------------------------------------------


def block_defs(kind: str, cfg):
    if kind in (cfgbase.ATTN, cfgbase.LOCAL_ATTN):
        return {"attn": attn.attn_defs(cfg), "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg)}
    if kind == cfgbase.ATTN_MOE:
        return {"attn": attn.attn_defs(cfg), "norm2": norm_defs(cfg), "moe": moe_mod.moe_defs(cfg)}
    if kind == cfgbase.RECURRENT:
        return {"rec": rglru_mod.rglru_defs(cfg), "norm2": norm_defs(cfg), "mlp": mlp_defs(cfg)}
    if kind == cfgbase.MLSTM:
        return {"mlstm": xlstm_mod.mlstm_defs(cfg)}
    if kind == cfgbase.SLSTM:
        return {"slstm": xlstm_mod.slstm_defs(cfg)}
    raise ValueError(kind)


def model_defs(cfg):
    defs: Dict[str, Any] = dict(embed_defs(cfg))
    scan = {}
    for i, kind in enumerate(cfg.pattern):
        scan[f"sub{i}"] = stack_defs(block_defs(kind, cfg), cfg.num_scan_groups)
    defs["scan"] = scan
    for j, kind in enumerate(cfg.tail_kinds):
        defs[f"tail{j}"] = block_defs(kind, cfg)
    defs["final_norm"] = norm_defs(cfg)
    return defs


# ---------------------------------------------------------------------------
# Cache defs (ShapeDtypeStructs — allocated by the serving engine / dry-run)
# ---------------------------------------------------------------------------


def block_cache_spec(kind: str, cfg, batch: int, capacity: int):
    K, hd = cfg.num_kv_heads, cfg.head_dim
    R = cfg.rglru_dim or cfg.d_model
    F2 = int(cfg.mlstm_proj_factor * cfg.d_model)
    W = cfg.conv1d_width
    cdt = jnp.dtype(cfg.dtype)
    if kind in (cfgbase.ATTN, cfgbase.ATTN_MOE):
        cap = capacity if cfg.sliding_window is None else min(capacity, cfg.sliding_window)
        return {"k": jax.ShapeDtypeStruct((batch, cap, K, hd), cdt),
                "v": jax.ShapeDtypeStruct((batch, cap, K, hd), cdt)}
    if kind == cfgbase.LOCAL_ATTN:
        cap = min(capacity, cfg.local_window or capacity)
        return {"k": jax.ShapeDtypeStruct((batch, cap, K, hd), cdt),
                "v": jax.ShapeDtypeStruct((batch, cap, K, hd), cdt)}
    if kind == cfgbase.RECURRENT:
        return {"h": jax.ShapeDtypeStruct((batch, R), jnp.float32),
                "conv": jax.ShapeDtypeStruct((batch, W - 1, R), cdt)}
    if kind == cfgbase.MLSTM:
        H = cfg.num_heads
        mhd = F2 // H
        return {"state": (jax.ShapeDtypeStruct((batch, H, mhd, mhd), jnp.float32),
                          jax.ShapeDtypeStruct((batch, H, mhd), jnp.float32),
                          jax.ShapeDtypeStruct((batch, H), jnp.float32)),
                "conv": jax.ShapeDtypeStruct((batch, W - 1, F2), cdt)}
    if kind == cfgbase.SLSTM:
        D = cfg.d_model
        st = jax.ShapeDtypeStruct((batch, D), jnp.float32)
        return {"state": (st, st, st, st)}
    raise ValueError(kind)


def _stack_spec(spec, n):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)


def cache_spec(cfg, batch: int, capacity: int):
    c: Dict[str, Any] = {"scan": {}}
    for i, kind in enumerate(cfg.pattern):
        c["scan"][f"sub{i}"] = _stack_spec(
            block_cache_spec(kind, cfg, batch, capacity), cfg.num_scan_groups)
    for j, kind in enumerate(cfg.tail_kinds):
        c[f"tail{j}"] = block_cache_spec(kind, cfg, batch, capacity)
    return c


def init_cache(cfg, batch: int, capacity: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, capacity))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _attn_mixer(p, x, cfg, *, kind, positions, mode, cache, cache_len,
                decode_attn_fn, prefill_len=None, block_tables=None):
    """Attention temporal mixer (pre-norm residual handled by caller).

    ``cfg.use_pallas`` routes the hot spots to the TPU kernels
    (repro.kernels); the default XLA path is what the dry-run lowers.
    ``prefill_len`` (traced scalar) marks the valid prompt prefix when the
    input is right-padded to a prefill bucket — the cache write then keeps
    the last real positions, not the padded tail.

    ``block_tables`` switches the cache layout to paged: cache leaves are a
    shared page pool [P, page_size, K, hd] and reads/writes route through the
    per-sequence block table (full attention only — the serving engine gates
    paged mode to non-windowed archs). ``mode == "extend"`` continues a
    partially-filled cache: a chunk at positions [cache_len, cache_len+S)
    attends to the cached prefix plus itself (chunked prefill / shared-prefix
    suffix prefill).
    """
    window = cfg.sliding_window if kind != cfgbase.LOCAL_ATTN else cfg.local_window
    q, k, v = attn.qkv_proj(p, x, cfg, positions)
    if mode == "decode" and block_tables is not None:
        ps = cache["k"].shape[1]
        kc, vc = attn.paged_cache_update(cache["k"], cache["v"], k, v,
                                         block_tables, cache_len, ps)
        if cfg.use_pallas:
            from repro.kernels import paged_decode_attention as _kpda
            o = _kpda.paged_decode_attention(q, kc, vc, block_tables,
                                             cache_len, q_per_kv=cfg.q_per_kv)
        else:
            o = attn.paged_decode_attention_ref(q, kc, vc, block_tables,
                                                cache_len,
                                                q_per_kv=cfg.q_per_kv)
        new_cache = {"k": kc, "v": vc}
    elif mode == "decode":
        kc, vc = attn.cache_update(cache["k"], cache["v"], k, v, cache_len)
        if cfg.use_pallas:
            from repro.kernels import decode_attention as _kda
            o = _kda.decode_attention(q, kc, vc, cache_len,
                                      q_per_kv=cfg.q_per_kv, window=window,
                                      block_w=cfg.decode_block_w)
        else:
            o = decode_attn_fn(q, kc, vc, cache_len, q_per_kv=cfg.q_per_kv,
                               window=window)
        new_cache = {"k": kc, "v": vc}
    elif mode == "verify":
        # speculative verify: S draft tokens per row at PER-ROW positions
        # [cache_len[b], cache_len[b]+S). Linear full-attention caches write
        # ahead: writes of the padded draft tail are dropped, and
        # rejected-draft K/V needs no rollback because later reads mask by
        # cache position and K/V at accepted positions is causally
        # independent of rejected tokens. Ring (windowed) caches can't write
        # ahead — a ring write destroys the overwritten position — so they
        # attend against a position-ordered view + the draft chunk and stage
        # the chunk K/V for ``verify_commit`` to ring-splice at each row's
        # accepted length.
        S = k.shape[1]
        clens = jnp.asarray(cache_len, jnp.int32).reshape(-1)
        lens = (prefill_len if prefill_len is not None else jnp.int32(S))
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < \
            jnp.reshape(lens, (-1, 1))
        valid = jnp.broadcast_to(valid, (k.shape[0], S))
        if window is not None:
            kv = jnp.concatenate([attn.ring_verify_view(cache["k"], clens),
                                  k.astype(cache["k"].dtype)], axis=1)
            vv = jnp.concatenate([attn.ring_verify_view(cache["v"], clens),
                                  v.astype(cache["v"].dtype)], axis=1)
            o = attn.spec_attention_ring(q, kv, vv, clens,
                                         q_per_kv=cfg.q_per_kv, window=window)
            new_cache = {"k": cache["k"], "v": cache["v"],
                         "k_new": k, "v_new": v}
        elif block_tables is not None:
            ps = cache["k"].shape[1]
            kc, vc = attn.paged_spec_cache_update(
                cache["k"], cache["v"], k, v, block_tables, clens, valid, ps)
            o = attn.spec_attention(q, attn.paged_view(kc, block_tables),
                                    attn.paged_view(vc, block_tables), clens,
                                    q_per_kv=cfg.q_per_kv)
            new_cache = {"k": kc, "v": vc}
        else:
            kc, vc = attn.spec_cache_update(cache["k"], cache["v"], k, v,
                                            clens, valid)
            o = attn.spec_attention(q, kc, vc, clens, q_per_kv=cfg.q_per_kv)
            new_cache = {"k": kc, "v": vc}
    elif mode == "extend":
        # chunk positions [start, start+S); first `prefill_len` rows valid
        S = k.shape[1]
        start = cache_len
        length = prefill_len if prefill_len is not None else jnp.int32(S)
        qpos = start + jnp.arange(S, dtype=jnp.int32)
        if block_tables is not None:
            if k.shape[0] != 1:
                raise NotImplementedError(
                    "paged extend writes one sequence per call (the engine "
                    f"prefills slot by slot); got batch {k.shape[0]}")
            ps = cache["k"].shape[1]
            kc = attn.paged_chunk_write(cache["k"], k, block_tables[0],
                                        start, ps)
            vc = attn.paged_chunk_write(cache["v"], v, block_tables[0],
                                        start, ps)
            kv = attn.paged_view(kc, block_tables)
            vv = attn.paged_view(vc, block_tables)
            o = attn.flash_attention(q, attn.repeat_kv(kv, cfg.q_per_kv),
                                     attn.repeat_kv(vv, cfg.q_per_kv),
                                     q_positions=qpos)
        else:
            cap = cache["k"].shape[1]
            if cap >= S and window is None:
                # linear cache: splice the chunk in place, attend to the whole
                # row (stale rows past the chunk are causal-masked exactly)
                kc = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
                o = attn.flash_attention(q, attn.repeat_kv(kc, cfg.q_per_kv),
                                         attn.repeat_kv(vc, cfg.q_per_kv),
                                         q_positions=qpos)
            else:
                # ring (windowed) cache: attend to a position-ordered view of
                # the last `cap` positions + the chunk, then ring-splice
                kseq = jnp.concatenate(
                    [attn.ring_order(cache["k"], start), k.astype(cache["k"].dtype)], axis=1)
                vseq = jnp.concatenate(
                    [attn.ring_order(cache["v"], start), v.astype(cache["v"].dtype)], axis=1)
                o = attn.flash_attention(
                    q, attn.repeat_kv(kseq, cfg.q_per_kv),
                    attn.repeat_kv(vseq, cfg.q_per_kv), window=window,
                    q_positions=cap + jnp.arange(S, dtype=jnp.int32),
                    k_start=jnp.maximum(cap - start, 0))
                kc = attn.ring_extend_write(cache["k"], k, start, length)
                vc = attn.ring_extend_write(cache["v"], v, start, length)
        new_cache = {"k": kc, "v": vc}
    else:
        kr = attn.repeat_kv(k, cfg.q_per_kv)
        vr = attn.repeat_kv(v, cfg.q_per_kv)
        if cfg.use_pallas:
            from repro.kernels import flash_attention as _kfa
            o = _kfa.flash_attention(q, kr, vr, window=window)
        else:
            o = attn.flash_attention(q, kr, vr, window=window,
                                     q_positions=positions[0])
        if mode == "prefill":
            cap = cache["k"].shape[1]
            S = k.shape[1]
            if cap >= S:
                # right-padding is harmless here: padded rows land at
                # positions >= prefill_len, which decode masks by cache_len
                kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            elif prefill_len is None:
                # windowed cache: keep the last `cap` positions, ring-aligned
                k_tail, v_tail = k[:, S - cap:], v[:, S - cap:]
                roll = (S - cap) % cap
                kc = jnp.roll(k_tail, shift=roll, axis=1).astype(cache["k"].dtype)
                vc = jnp.roll(v_tail, shift=roll, axis=1).astype(cache["v"].dtype)
            else:
                # windowed cache under padding: keep positions
                # [prefill_len - cap, prefill_len), ring-aligned at p % cap
                def ring_write(knew, tgt):
                    padded = jnp.concatenate(
                        [jnp.zeros_like(knew[:, :cap]), knew], axis=1)
                    tail = jax.lax.dynamic_slice_in_dim(padded, prefill_len,
                                                        cap, axis=1)
                    return jnp.roll(tail, shift=prefill_len % cap,
                                    axis=1).astype(tgt.dtype)
                kc, vc = ring_write(k, cache["k"]), ring_write(v, cache["v"])
            new_cache = {"k": kc, "v": vc}
        else:
            new_cache = cache
    return attn.out_proj(p, o), new_cache


def apply_block(kind, p, x, cfg, *, positions, mode, cache, cache_len,
                decode_attn_fn, prefill_len=None, prefill_mask=None,
                block_tables=None):
    """One residual block. Returns (x', new_cache, aux_loss).

    In ``mode="verify"`` the returned "cache" of stateful blocks (recurrent /
    mLSTM / sLSTM / ring attention) is a *staged* record — per-position
    states plus the pre-verify state — that ``verify_commit`` resolves to a
    real cache once the accept step has picked each row's accepted length.
    Full-attention blocks commit in place (position-masked write-ahead).
    """
    aux = jnp.zeros((), jnp.float32)
    rec_mode = mode if mode in ("decode", "extend", "verify") else "full"
    rec_len = prefill_len if mode in ("prefill", "extend", "verify") else None
    rec_mask = prefill_mask if mode in ("prefill", "extend", "verify") else None
    if kind in (cfgbase.ATTN, cfgbase.ATTN_MOE, cfgbase.LOCAL_ATTN):
        h = apply_norm(p["attn"]["norm"], x, cfg)
        o, new_cache = _attn_mixer(p["attn"], h, cfg, kind=kind, positions=positions,
                                   mode=mode, cache=cache, cache_len=cache_len,
                                   decode_attn_fn=decode_attn_fn,
                                   prefill_len=rec_len,
                                   block_tables=block_tables)
        x = x + o
        h2 = apply_norm(p["norm2"], x, cfg)
        if kind == cfgbase.ATTN_MOE:
            y, aux = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        return x + y, new_cache, aux
    if kind == cfgbase.RECURRENT:
        h = apply_norm(p["rec"]["norm"], x, cfg)
        o, new_cache = rglru_mod.apply_recurrent_mixer(
            p["rec"], h, cfg, cache=cache, mode=rec_mode,
            length=rec_len, mask=rec_mask)
        x = x + o
        h2 = apply_norm(p["norm2"], x, cfg)
        return x + apply_mlp(p["mlp"], h2, cfg), new_cache, aux
    if kind == cfgbase.MLSTM:
        h = apply_norm(p["mlstm"]["norm"], x, cfg)
        o, new_cache = xlstm_mod.apply_mlstm(
            p["mlstm"], h, cfg, cache=cache, mode=rec_mode,
            length=rec_len, mask=rec_mask)
        return x + o, new_cache, aux
    if kind == cfgbase.SLSTM:
        h = apply_norm(p["slstm"]["norm"], x, cfg)
        o, new_cache = xlstm_mod.apply_slstm(
            p["slstm"], h, cfg, cache=cache, mode=rec_mode,
            length=rec_len, mask=rec_mask)
        return x + o, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The stack
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _diff_barrier(x):
    """``optimization_barrier`` with a gradient rule (none exists upstream):
    the cotangent is barrier'd too, so the backward layers loop keeps the
    same LICM protection as the forward one."""
    return jax.lax.optimization_barrier(x)


def _diff_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _diff_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_diff_barrier.defvjp(_diff_barrier_fwd, _diff_barrier_bwd)


def _superblock(params_g, cache_g, x, cfg, *, positions, mode, cache_len,
                decode_attn_fn, prefill_len=None, prefill_mask=None,
                block_tables=None):
    """Apply one period of the pattern. Returns (x, new_cache_g, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    x = constrain(x, "batch", None, None)
    # Barrier: stops XLA LICM from hoisting per-layer converts of the saved
    # residual stack out of the (backward) layers loop — that hoist would
    # materialize an f32 copy of the whole [L, B, S, D] stack (MaxText does
    # the same around scanned blocks).
    x = _diff_barrier(x)
    for i, kind in enumerate(cfg.pattern):
        sub_cache = cache_g.get(f"sub{i}") if cache_g else None
        x, nc, a = apply_block(kind, params_g[f"sub{i}"], x, cfg,
                               positions=positions, mode=mode, cache=sub_cache,
                               cache_len=cache_len, decode_attn_fn=decode_attn_fn,
                               prefill_len=prefill_len, prefill_mask=prefill_mask,
                               block_tables=block_tables)
        new_cache[f"sub{i}"] = nc
        aux = aux + a
    return x, new_cache, aux


def apply_stack(params, x, cfg, *, positions, mode, cache=None, cache_len=None,
                decode_attn_fn=None, prefill_len=None, prefill_mask=None,
                block_tables=None):
    """Run all layers. Returns (x, new_cache, aux_loss_sum)."""
    decode_attn_fn = decode_attn_fn or attn.decode_attention
    use_cache = cache is not None
    scan_cache = cache["scan"] if use_cache else None

    def body(carry, xs):
        x, aux = carry
        params_g, cache_g = xs
        x, new_cache_g, a = _superblock(params_g, cache_g, x, cfg,
                                        positions=positions, mode=mode,
                                        cache_len=cache_len,
                                        decode_attn_fn=decode_attn_fn,
                                        prefill_len=prefill_len,
                                        prefill_mask=prefill_mask,
                                        block_tables=block_tables)
        return (x, aux + a), new_cache_g

    if cfg.remat_policy != "none" and mode == "train":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers and cfg.num_scan_groups > 1:
        if use_cache:
            (x, aux), new_scan_cache = jax.lax.scan(body, (x, aux0),
                                                    (params["scan"], scan_cache))
        else:
            def body_nocache(carry, params_g):
                return body(carry, (params_g, None))[0], None
            (x, aux), _ = jax.lax.scan(body_nocache, (x, aux0), params["scan"])
            new_scan_cache = None
    else:
        aux = aux0
        slices = []
        for g in range(cfg.num_scan_groups):
            params_g = jax.tree.map(lambda v: v[g], params["scan"])
            cache_g = jax.tree.map(lambda v: v[g], scan_cache) if use_cache else None
            (x, aux), nc = body((x, aux), (params_g, cache_g))
            slices.append(nc)
        if not use_cache:
            new_scan_cache = None
        elif slices:
            new_scan_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
        else:
            # num_layers < len(pattern): every layer is a tail layer and the
            # scan cache is zero-size — pass it through unchanged
            new_scan_cache = scan_cache

    new_cache = {"scan": new_scan_cache} if use_cache else None
    for j, kind in enumerate(cfg.tail_kinds):
        tail_cache = cache.get(f"tail{j}") if use_cache else None
        x, nc, a = apply_block(kind, params[f"tail{j}"], x, cfg,
                               positions=positions, mode=mode, cache=tail_cache,
                               cache_len=cache_len, decode_attn_fn=decode_attn_fn,
                               prefill_len=prefill_len, prefill_mask=prefill_mask,
                               block_tables=block_tables)
        aux = aux + a
        if use_cache:
            new_cache[f"tail{j}"] = nc
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _inputs_to_x(params, batch, cfg):
    """Resolve tokens vs precomputed frame embeddings (modality stub)."""
    if cfg.modality == "audio_frames":
        x = batch["frames"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos(batch["positions"], cfg.d_model, x.dtype)
    return x


def forward_logits(params, batch, cfg, *, mode="train", cache=None, cache_len=None,
                   decode_attn_fn=None, prefill_len=None, block_tables=None,
                   with_logits=True):
    """``with_logits`` selects how much of the unembed matmul runs:

    * False    — skip final-norm + unembed, return None logits (intermediate
                 prefill chunks only need the cache side effects, and the
                 unembed is the dominant matmul at real vocab sizes).
    * "last"   — unembed only the position ``prefill_len - 1`` (or the final
                 position), returning [B, 1, V]: all a prompt's final chunk
                 needs to seed sampling. Scalar ``prefill_len`` only.
    * "all" / True — unembed every position, [B, S, V]: the speculative
                 verify step scores all draft positions from one forward.

    ``prefill_len`` may be a traced scalar (uniform valid prefix — bucketed
    prefill / extend) or a [B] vector (per-row valid counts — verify mode).
    """
    x = _inputs_to_x(params, batch, cfg)
    prefill_mask = None
    if prefill_len is not None:
        S = x.shape[1]
        plen = jnp.reshape(jnp.asarray(prefill_len, jnp.int32), (-1, 1))
        prefill_mask = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :] < plen,
            (x.shape[0], S))
    x, new_cache, aux = apply_stack(params, x, cfg, positions=batch["positions"],
                                    mode=mode, cache=cache, cache_len=cache_len,
                                    decode_attn_fn=decode_attn_fn,
                                    prefill_len=prefill_len,
                                    prefill_mask=prefill_mask,
                                    block_tables=block_tables)
    if not with_logits:
        return None, new_cache, aux
    x = apply_norm(params["final_norm"], x, cfg)
    if with_logits == "last":
        last = (prefill_len - 1 if prefill_len is not None
                else x.shape[1] - 1)
        x = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
    logits = unembed(params, x, cfg)
    return logits, new_cache, aux


def train_loss(params, batch, cfg, *, decode_attn_fn=None):
    """Causal LM loss. batch: tokens/frames [B,S], labels [B,S], positions."""
    logits, _, aux = forward_logits(params, batch, cfg, mode="train")
    labels = batch["labels"]
    V = cfg.padded_vocab
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < cfg.vocab_size)
    nll = jnp.where(mask, lse - ll, 0.0)
    nll = constrain(nll, "batch", None)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux, {"nll": loss, "aux": aux}


def prefill(params, batch, cfg, cache, *, length=None, decode_attn_fn=None,
            with_logits=True):
    """Fill the cache from a prompt. Returns (logits [B,S,V], cache').

    ``length`` (traced scalar, optional): valid prompt length when tokens are
    right-padded to a bucket — recurrent state, conv state, and windowed KV
    caches then match an unpadded prefill of the first ``length`` tokens.
    ``with_logits="last"`` unembeds only position ``length - 1`` ([B,1,V]) —
    all the serving engine needs to seed sampling, skipping the other
    bucket-1 rows of the dominant matmul.
    """
    logits, new_cache, _ = forward_logits(params, batch, cfg, mode="prefill",
                                          cache=cache, cache_len=jnp.zeros((), jnp.int32),
                                          prefill_len=length,
                                          decode_attn_fn=decode_attn_fn,
                                          with_logits=with_logits)
    return logits, new_cache


def decode_step(params, batch, cfg, cache, cache_len, *, decode_attn_fn=None,
                block_tables=None):
    """One decode step. batch tokens [B,1]; returns (logits [B,1,V], cache').

    ``block_tables`` [B, P] int32 switches attention caches to the paged
    layout (cache leaves are page pools; see serving/kvpool.py).
    """
    logits, new_cache, _ = forward_logits(params, batch, cfg, mode="decode",
                                          cache=cache, cache_len=cache_len,
                                          decode_attn_fn=decode_attn_fn,
                                          block_tables=block_tables)
    return logits, new_cache


def extend(params, batch, cfg, cache, cache_len, *, length=None,
           decode_attn_fn=None, block_tables=None, with_logits=True):
    """Prefill continuation: a chunk of S tokens at positions
    [cache_len, cache_len+S) against an already partially-filled cache.

    Attention layers attend to the cached prefix + the chunk; recurrent /
    conv / xLSTM layers resume from their cached state. ``length`` (traced
    scalar) marks the valid chunk prefix when the chunk is right-padded to a
    bucket. Powers chunked prefill past the largest bucket and shared-prefix
    suffix prefill in paged mode. Returns (logits [B,S,V], cache');
    ``with_logits=False`` returns (None, cache') and skips the unembed —
    only a prompt's final chunk needs logits.
    """
    logits, new_cache, _ = forward_logits(params, batch, cfg, mode="extend",
                                          cache=cache, cache_len=cache_len,
                                          prefill_len=length,
                                          decode_attn_fn=decode_attn_fn,
                                          block_tables=block_tables,
                                          with_logits=with_logits)
    return logits, new_cache


def verify(params, batch, cfg, cache, cache_lens, *, lens=None,
           decode_attn_fn=None, block_tables=None):
    """Speculative-decode verify: score S draft tokens per row in ONE forward.

    batch tokens [B, S] are ``[last, d_1 .. d_k, pad...]`` per row at per-row
    positions ``[cache_lens[b], cache_lens[b]+S)``; ``lens`` [B] counts the
    valid inputs (k+1) — padded-tail cache writes are dropped and padded
    logits are garbage the acceptance step never reads. Returns
    (logits [B,S,V], staged): ``logits[:, i]`` is the target distribution
    for the token following input i (sampler.accept_batched consumes it).

    For pure linear full-attention caches ``staged`` IS the new cache
    (write-ahead, position-masked). Stateful blocks (recurrent / conv /
    mLSTM / sLSTM, ring KV) stage per-position states instead — pass
    ``staged`` plus the accept step's per-row emitted counts to
    ``verify_commit`` to resolve the final cache. Works for every arch.
    """
    logits, new_cache, _ = forward_logits(params, batch, cfg, mode="verify",
                                          cache=cache, cache_len=cache_lens,
                                          prefill_len=lens,
                                          decode_attn_fn=decode_attn_fn,
                                          block_tables=block_tables,
                                          with_logits="all")
    return logits, new_cache


def _commit_block(kind, cfg, staged, clens, ns, valid):
    """Resolve one block's verify record to a committed cache (see
    apply_block's verify contract)."""
    window = (cfg.sliding_window if kind != cfgbase.LOCAL_ATTN
              else cfg.local_window)
    if kind in (cfgbase.ATTN, cfgbase.ATTN_MOE) and window is None:
        return staged                       # write-ahead already committed
    if kind in (cfgbase.ATTN, cfgbase.ATTN_MOE, cfgbase.LOCAL_ATTN):
        return attn.ring_verify_commit(staged, clens, ns, valid)
    if kind == cfgbase.RECURRENT:
        return rglru_mod.verify_commit(staged, ns, valid)
    if kind == cfgbase.MLSTM:
        return xlstm_mod.mlstm_verify_commit(staged, ns, valid)
    if kind == cfgbase.SLSTM:
        return xlstm_mod.slstm_verify_commit(staged, ns, valid)
    raise ValueError(kind)


def verify_commit(cfg, staged, cache_lens, ns, lens):
    """Resolve a ``verify`` call's staged record to the committed cache.

    ns [B]: tokens emitted per row by ``sampler.accept_batched`` (= accepted
    drafts + 1 correction/bonus = inputs actually consumed); lens [B]: the
    verify call's valid-input counts — rows with ``lens == 0`` sat the step
    out and keep their pre-verify state bit-exactly. The whole rewind is
    gathers and ring splices — no second forward — which is what lets
    stateful archs share the engine's ONE-jit'd-verify-per-step fast path.
    """
    clens = jnp.asarray(cache_lens, jnp.int32).reshape(-1)
    ns = jnp.asarray(ns, jnp.int32).reshape(-1)
    valid = jnp.asarray(lens, jnp.int32).reshape(-1) > 0
    new_cache = {"scan": {}}
    if cfg.num_scan_groups == 0:
        # num_layers < len(pattern): apply_stack passed the zero-size scan
        # cache through unchanged — no staged records to resolve
        new_cache["scan"] = staged["scan"]
    else:
        for i, kind in enumerate(cfg.pattern):
            fn = functools.partial(_commit_block, kind, cfg,
                                   clens=clens, ns=ns, valid=valid)
            new_cache["scan"][f"sub{i}"] = jax.vmap(
                lambda s, fn=fn: fn(s))(staged["scan"][f"sub{i}"])
    for j, kind in enumerate(cfg.tail_kinds):
        new_cache[f"tail{j}"] = _commit_block(kind, cfg, staged[f"tail{j}"],
                                              clens, ns, valid)
    return new_cache
