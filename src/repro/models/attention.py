"""GQA attention: blocked (flash-style) XLA path + decode path + param defs.

The full/train/prefill path never materializes the [S, T] score matrix: it
scans q-blocks and kv-blocks with online-softmax accumulators (the same
algorithm the Pallas kernel implements on TPU; `kernels/flash_attention.py`
is the hardware path, this is the XLA path the dry-run lowers).

Decode attends a single new token against a (possibly ring-buffered) KV cache.
The distributed variant — KV cache sequence-sharded over the `model` axis with
a log-sum-exp psum combine — lives in `repro.distributed.collectives`.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import (ParamDef, apply_norm, mlp_defs, norm_defs,
                                 rms_norm_headwise, rotary_embed)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def attn_defs(cfg):
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "norm": norm_defs(cfg),
        "wq": ParamDef((D, H, hd), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": ParamDef((D, K, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": ParamDef((D, K, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        # wo's head dim is the contraction side of the output projection —
        # own logical axis so serve can replicate it (bit-exact, see
        # distributed/sharding.py) while train keeps the Megatron layout
        "wo": ParamDef((H, hd, D), ("heads_in", "head_dim", "embed"), init="scaled"),
    }
    if cfg.attn_bias:
        d["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        d["bk"] = ParamDef((K, hd), ("kv_heads", "head_dim"), init="zeros")
        d["bv"] = ParamDef((K, hd), ("kv_heads", "head_dim"), init="zeros")
    return d


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def qkv_proj(p, x, cfg, positions):
    """x [B,S,D] -> q [B,S,H,hd], k,v [B,S,K,hd] with rope/qk-norm applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q, k = rms_norm_headwise(q), rms_norm_headwise(k)
    if cfg.pos_emb == "rope":
        q = rotary_embed(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = rotary_embed(k, positions, cfg.rope_theta, cfg.rotary_pct)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def out_proj(p, o):
    # "heads_act": train/decode keep heads sharded (Megatron); serve gathers
    # them here so the contraction over heads is never split across devices
    o = constrain(o, "batch", None, "heads_act", None)
    return constrain(jnp.einsum("bshk,hkd->bsd", o, p["wo"]), "batch", None, None)


def repeat_kv(k, q_per_kv: int):
    """[B,S,K,hd] -> [B,S,K*G,hd] by repeating each KV head G times."""
    if q_per_kv == 1:
        return k
    B, S, K, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, K, q_per_kv, hd))
    return constrain(k.reshape(B, S, K * q_per_kv, hd), "batch", None, "heads", None)


# ---------------------------------------------------------------------------
# Blocked full attention (train / prefill)
# ---------------------------------------------------------------------------


def _block_mask(q_pos, k_pos, window: Optional[int]):
    """[bq, bkv] bool mask: causal + optional sliding/local window."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention(q, k, v, *, window: Optional[int] = None,
                    block_q: int = 512, block_kv: int = 512,
                    q_positions=None, k_start=None):
    """Causal flash attention, pure-XLA. q,k,v: [B, S(T), H, hd] (KV repeated).

    ``q_positions``: int32 [S] *runtime* positions of the q rows (k rows are
    positions 0..T-1). Being a runtime input keeps the per-block masks inside
    the scan bodies — if they were trace-time constants XLA's LICM would hoist
    and materialize all (q-block × kv-block) masks as a giant temp.

    ``k_start``: optional traced scalar — k rows below it are masked out.
    Extend mode passes a position-ordered ring-cache view whose leading rows
    may predate position 0 (unwritten); this masks them.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    if q_positions is None:
        q_positions = jnp.arange(S, dtype=jnp.int32)
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    # pad S/T to block multiples
    Sp = -(-S // block_q) * block_q
    Tp = -(-T // block_kv) * block_kv
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    posp = jnp.pad(q_positions.astype(jnp.int32), (0, Sp - S),
                   constant_values=-(2 ** 30))
    nq, nk = Sp // block_q, Tp // block_kv
    scale = 1.0 / math.sqrt(hd)

    # [nq, B, bq, H, hd] / [nk, B, bkv, H, hd]
    qb = jnp.moveaxis(qp.reshape(B, nq, block_q, H, hd), 1, 0)
    kb = jnp.moveaxis(kp.reshape(B, nk, block_kv, H, hd), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nk, block_kv, H, hd), 1, 0)
    pb = posp.reshape(nq, block_q)

    def q_step(_, qi_blk):
        q_pos, q_blk = qi_blk                                  # [bq] runtime

        def kv_step(carry, kj_blk):
            m_prev, l_prev, o_prev = carry
            kj, k_blk, v_blk = kj_blk
            k_pos = kj * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bqhk,bvhk->bhqv", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, window)
            if Tp != T:
                mask &= (k_pos < T)[None, :]
            if k_start is not None:
                mask &= (k_pos >= k_start)[None, :]
            s = s + jnp.where(mask, 0.0, NEG_INF)              # [bq,bkv] bias
            m_cur = jnp.max(s, axis=-1)                       # [B,H,bq]
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            o_new = o_prev * corr[..., None] + jnp.einsum(
                "bhqv,bvhk->bhqk", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        init = (jnp.full((B, H, block_q), NEG_INF, jnp.float32),
                jnp.zeros((B, H, block_q), jnp.float32),
                jnp.zeros((B, H, block_q, hd), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_step, init,
                                    (jnp.arange(nk), kb, vb))
        o = o / jnp.maximum(l, 1e-30)[..., None]               # [B,H,bq,hd]
        return None, jnp.moveaxis(o, 1, 2)                     # -> [B,bq,H,hd]

    _, ob = jax.lax.scan(q_step, None, (pb, qb))               # [nq,B,bq,H,hd]
    out = jnp.moveaxis(ob, 0, 1).reshape(B, Sp, H, hd)[:, :S]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single-device semantics; sharded version in distributed/)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, cache_len, *, q_per_kv: int,
                     window: Optional[int] = None):
    """q [B,1,H,hd] against cache [B,W,K,hd]; valid positions < cache_len+1.

    The new token's K/V must already be written into the cache (at slot
    ``cache_len % W``). GQA is computed grouped — no KV repetition.
    """
    B, W, K, hd = k_cache.shape
    H = q.shape[2]
    G = q_per_kv
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bwkh->bkgw", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos = jnp.arange(W)
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        clen = clen[None]                              # -> [1] or [B]
    n_valid = jnp.minimum(clen + 1, W)                 # [1|B]
    valid = pos[None, :] < n_valid[:, None]            # [1|B, W]
    if window is not None:
        # slots older than `window` positions are invalid (ring overwrite makes
        # this automatic when W == window; keep mask for W > window)
        age = (clen % jnp.maximum(W, 1))[:, None] - pos[None, :]
        age = jnp.where(age < 0, age + W, age)
        valid &= age < jnp.minimum(window, n_valid + 1)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, cache_len):
    """Write k_new/v_new [B,1,K,hd] at ring slot cache_len % W.

    ``cache_len`` scalar → uniform dynamic-update-slice (the dry-run/train
    path, friendly to sequence-sharded caches); vector [B] → per-row scatter
    (continuous batching: every slot has its own length).
    """
    W = k_cache.shape[1]
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        slot = clen % W
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
        return k_cache, v_cache
    rows = jnp.arange(k_cache.shape[0])
    slot = clen % W
    k_cache = k_cache.at[rows, slot].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[rows, slot].set(v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Speculative verify: S draft tokens per row at PER-ROW positions
# [cache_lens[b], cache_lens[b]+S) — the batched multi-token decode that
# scores a whole draft in one forward (serving engine spec path). Linear
# (non-ring) caches write ahead: rejected-draft K/V beyond the accepted
# prefix is rolled back for free because every later read masks by cache
# position, and causality guarantees K/V at accepted positions never
# depended on rejected tokens. Ring (windowed) caches can't write ahead — a
# ring write destroys the overwritten position — so they attend a
# position-ordered view + the draft chunk (ring_verify_view /
# spec_attention_ring) and splice only the accepted rows afterwards
# (ring_verify_commit, driven by transformer.verify_commit).
# ---------------------------------------------------------------------------


def spec_cache_update(k_cache, v_cache, k_new, v_new, cache_lens, valid):
    """Verify-step write: k_new/v_new [B,S,K,hd] land at positions
    ``cache_lens[b] + s`` of row b (linear cache). Rows with ``valid[b,s]``
    False (padded draft tail) are dropped, not written."""
    B, S = k_new.shape[:2]
    W = k_cache.shape[1]
    pos = cache_lens[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    pos = jnp.where(valid, pos, W)               # out of bounds -> dropped
    rows = jnp.arange(B)[:, None]
    k_cache = k_cache.at[rows, pos].set(k_new.astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[rows, pos].set(v_new.astype(v_cache.dtype), mode="drop")
    return k_cache, v_cache


def paged_spec_cache_update(pool_k, pool_v, k_new, v_new, block_tables,
                            cache_lens, valid, page_size: int):
    """Paged verify-step write: positions route through per-row block tables;
    invalid rows land on the trash page (kvpool.TRASH_PAGE == 0), the same
    place block-table padding already sends masked-out decode writes."""
    B, S = k_new.shape[:2]
    nbt = block_tables.shape[1]
    rows = jnp.arange(B)[:, None]
    pos = cache_lens[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    pi = jnp.clip(pos // page_size, 0, nbt - 1)
    page = jnp.where(valid, block_tables[rows, pi], 0)
    off = pos % page_size
    pool_k = pool_k.at[page, off].set(k_new.astype(pool_k.dtype))
    pool_v = pool_v.at[page, off].set(v_new.astype(pool_v.dtype))
    return pool_k, pool_v


def ring_verify_view(cache, cache_lens):
    """Per-row position-ordered ring view for the verify step: row i of
    sequence b holds position ``cache_lens[b] - cap + i`` (negative =
    unwritten, masked by the attention below)."""
    cap = cache.shape[1]
    return jax.vmap(lambda c, s: jnp.roll(c, -s, axis=0))(cache,
                                                          cache_lens % cap)


def spec_attention_ring(q, k_view, v_view, cache_lens, *, q_per_kv: int,
                        window: int):
    """Multi-token decode attention against a ring (windowed) cache view.

    q [B,S,H,hd] (query s of row b at position ``cache_lens[b] + s``) against
    ``concat(ring_verify_view(cache), chunk)`` [B,cap+S,K,hd]: view row i of
    sequence b uniformly holds position ``cache_lens[b] - cap + i``
    (cap = T - S), so one mask formula covers cached and draft keys. Queries
    attend causally within the sliding ``window``; nothing is written — the
    draft K/V is ring-spliced by ``ring_verify_commit`` only after the accept
    step picks each row's accepted length (a ring write is destructive, so
    the write-ahead trick of the linear-cache verify path can't be used).
    """
    B, S, H, hd = q.shape
    T = k_view.shape[1]
    cap = T - S
    K = k_view.shape[2]
    G = q_per_kv
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgh,bwkh->bkgsw", qg, k_view,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos_k = cache_lens[:, None] - cap + jnp.arange(T, dtype=jnp.int32)[None]
    pos_q = cache_lens[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    valid = ((pos_k[:, None, :] >= 0)
             & (pos_k[:, None, :] <= pos_q[:, :, None])
             & (pos_q[:, :, None] - pos_k[:, None, :] < window))  # [B,S,T]
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgsw,bwkh->bskgh", p.astype(v_view.dtype), v_view,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def ring_verify_commit(staged, cache_lens, ns, valid):
    """Commit a ring cache's verify step at each row's accepted length:
    splice the first ``ns[b]`` draft K/V rows into the ring (``ns = 0`` — an
    invalid row — leaves the ring bit-exact). staged:
    {"k", "v": the untouched pre-verify rings, "k_new", "v_new": [B,S,K,hd]}.
    """
    length = jnp.where(valid, ns, 0)

    def one(ck, cv, kn, vn, start, n):
        kc = ring_extend_write(ck[None], kn[None], start, n)[0]
        vc = ring_extend_write(cv[None], vn[None], start, n)[0]
        return kc, vc

    kc, vc = jax.vmap(one)(staged["k"], staged["v"], staged["k_new"],
                           staged["v_new"], cache_lens, length)
    return {"k": kc, "v": vc}


def spec_attention(q, k_cache, v_cache, cache_lens, *, q_per_kv: int):
    """Multi-token decode attention for the verify step.

    q [B,S,H,hd] (query s of row b sits at position ``cache_lens[b] + s``)
    against a linear cache [B,W,K,hd] whose draft K/V is already written;
    query s attends exactly the positions <= its own, so the math matches S
    successive ``decode_attention`` calls. S is the draft length + 1 (tiny),
    so the [S, W] score slab per head stays cheap.
    """
    B, S, H, hd = q.shape
    W = k_cache.shape[1]
    K = k_cache.shape[2]
    G = q_per_kv
    qg = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgh,bwkh->bkgsw", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos_q = cache_lens[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    valid = jnp.arange(W)[None, None, :] <= pos_q[:, :, None]      # [B,S,W]
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgsw,bwkh->bskgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Extend (chunked-prefill continuation): a chunk of S new tokens at positions
# [start, start+S) attends to the already-filled cache prefix + itself
# ---------------------------------------------------------------------------


def ring_order(cache, start):
    """Position-ordered view of a ring cache: row i holds position
    ``start - cap + i`` (ring slot ``p % cap`` holds position p)."""
    cap = cache.shape[1]
    return jnp.roll(cache, shift=-(start % cap), axis=1)


def ring_extend_write(cache, chunk, start, length):
    """Splice a prefill chunk into a ring cache.

    cache [B, cap, ...] (ring: position p at slot p % cap, filled below
    ``start``); chunk [B, S, ...] holds positions [start, start+S) of which
    the first ``length`` are valid. Returns the ring holding the last ``cap``
    positions of the sequence ending at ``start + length``.
    """
    cap = cache.shape[1]
    seq = jnp.concatenate([ring_order(cache, start),
                           chunk.astype(cache.dtype)], axis=1)
    # seq row i holds position start - cap + i; the state for a sequence
    # ending at start+length is positions [start+length-cap, start+length)
    tail = jax.lax.dynamic_slice_in_dim(seq, length, cap, axis=1)
    return jnp.roll(tail, shift=(start + length) % cap, axis=1)


# ---------------------------------------------------------------------------
# Paged KV cache: one device pool of fixed-size pages, per-request block
# tables (serving/kvpool.py owns allocation; this is the data path)
# ---------------------------------------------------------------------------


def paged_view(pool, block_tables):
    """Gather a dense per-sequence view from the page pool.

    pool [P, ps, K, hd], block_tables [B, n] int32 -> [B, n*ps, K, hd];
    row ``w`` of sequence b is position w (pages are position-ordered).
    """
    g = pool[block_tables]
    B, n, ps = g.shape[:3]
    return g.reshape((B, n * ps) + g.shape[3:])


def paged_cache_update(pool_k, pool_v, k_new, v_new, block_tables, cache_len,
                       page_size: int):
    """Decode-step write: k_new/v_new [B,1,K,hd] land at position
    ``cache_len[b]`` of sequence b, routed through its block table."""
    clen = jnp.asarray(cache_len, jnp.int32)
    if clen.ndim == 0:
        clen = jnp.broadcast_to(clen, (block_tables.shape[0],))
    rows = jnp.arange(block_tables.shape[0])
    page = block_tables[rows, clen // page_size]
    off = clen % page_size
    pool_k = pool_k.at[page, off].set(k_new[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[page, off].set(v_new[:, 0].astype(pool_v.dtype))
    return pool_k, pool_v


def paged_chunk_write(pool, chunk, block_table_row, start, page_size: int):
    """Extend-chunk write: chunk [1, S, K, hd] at positions [start, start+S)
    of the (single) sequence whose block table row is [n] int32."""
    S = chunk.shape[1]
    pos = start + jnp.arange(S, dtype=jnp.int32)
    page = block_table_row[pos // page_size]
    off = pos % page_size
    return pool.at[page, off].set(chunk[0].astype(pool.dtype))


def paged_decode_attention_ref(q, pool_k, pool_v, block_tables, cache_len, *,
                               q_per_kv: int):
    """XLA reference for paged decode attention: gather pages into a dense
    view, then reuse the dense masking math (full attention only — the engine
    gates paged mode to non-windowed archs)."""
    kv = paged_view(pool_k, block_tables)
    vv = paged_view(pool_v, block_tables)
    return decode_attention(q, kv, vv, cache_len, q_per_kv=q_per_kv,
                            window=None)
