"""Sharded checkpointing: atomic step dirs, async save, elastic restore.

Format: one ``<step>/manifest.msgpack`` (tree structure, shapes, dtypes) plus
one raw buffer file per host-shard. On restore, arrays are re-sharded to the
CURRENT mesh (which may differ from the save-time mesh — elastic restart).
No orbax in this environment, so the format is self-contained.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomic synchronous save. Returns the step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                           for l in leaves]}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        with open(os.path.join(tmp, f"leaf_{i:05d}.npy"), "wb") as f:
            np.save(f, arr)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)                       # atomic publish
    with open(os.path.join(ckpt_dir, "latest"), "w") as f:
        f.write(os.path.basename(final))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread save (compute keeps running while IO drains)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    def save(self, step: int, tree):
        self.wait()
        # snapshot to host BEFORE backgrounding so later updates don't race
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self._pending = self._pool.submit(save, self.ckpt_dir, step, host_tree,
                                          keep=self.keep)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None


def latest_step_dir(ckpt_dir: str) -> Optional[str]:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        d = os.path.join(ckpt_dir, f.read().strip())
    return d if os.path.isdir(d) else None


def restore(ckpt_dir: str, like_tree, *, shardings=None) -> Any:
    """Restore the latest checkpoint into the structure of ``like_tree``.

    ``shardings``: optional matching tree of NamedShardings for the CURRENT
    mesh — arrays are placed per-shard (elastic restore onto a different
    device count).
    """
    d = latest_step_dir(ckpt_dir)
    if d is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == len(manifest["leaves"]), "tree structure changed"
    out = []
    shard_leaves = jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        with open(os.path.join(d, f"leaf_{i:05d}.npy"), "rb") as f:
            arr = np.load(f)
        assert tuple(arr.shape) == tuple(ref.shape), (arr.shape, ref.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, out), manifest["step"]
