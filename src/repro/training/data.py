"""Deterministic synthetic data pipeline with a restart-safe cursor.

Batches are a pure function of (seed, step) — after an elastic restart the
cursor (carried in the checkpointed train state) resumes exactly, and each
data-parallel host can slice its shard without coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab_size: int = 256


class SyntheticLM:
    """Markov-ish deterministic token stream (stable across restarts)."""

    def __init__(self, cfg: DataConfig, model_cfg=None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(key=c.seed + step))
        base = rng.integers(0, c.vocab_size, size=(c.global_batch, c.seq_len + 1),
                            dtype=np.int64)
        # inject structure so loss can actually fall: strong copy pattern —
        # positions not ≡0 (mod 3) repeat the token 1 or 2 slots earlier
        base[:, 1::3] = base[:, 0:-1:3]
        base[:, 2::3] = base[:, 1:-1:3]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        positions = np.broadcast_to(np.arange(c.seq_len, dtype=np.int32),
                                    tokens.shape)
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
               "positions": jnp.asarray(positions)}
        if self.model_cfg is not None and self.model_cfg.modality == "audio_frames":
            d = self.model_cfg.d_model
            frames = rng.standard_normal((c.global_batch, c.seq_len, d)).astype(np.float32)
            out["frames"] = jnp.asarray(frames, jnp.dtype(self.model_cfg.dtype))
            del out["tokens"]
        return out
