"""AdamW (+ global-norm clip) as pure pytree transforms — no optax installed.

Optimizer state is sharded exactly like the parameters (m/v mirror the param
tree, so the same PartitionSpecs apply). A float32 master copy is optional —
by default m/v are float32 and params update in their storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def opt_state_specs(param_specs_tree) -> OptState:
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_specs_tree)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), m=f32, v=f32)


def opt_state_pspecs(param_pspec_tree):
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), m=param_pspec_tree, v=param_pspec_tree)


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, m=new_m, v=new_v), {"grad_norm": gnorm, "lr": lr}
