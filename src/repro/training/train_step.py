"""Training step: loss + grad (+ microbatch accumulation) + AdamW update.

Microbatch accumulation (``accum_steps > 1``) bounds activation transients —
needed for the MoE giants at train_4k (DESIGN.md §8) — via a ``lax.scan`` over
microbatch slices, which is also how 1000-node runs keep HBM flat.

Optional gradient compression (int8 with error feedback) demonstrates the
distributed-optimization hook; it is OFF by default and exercised in tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.training.optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    accum_steps: int = 1
    compress_grads: bool = False


def _loss_fn(params, batch, cfg):
    loss, metrics = tfm.train_loss(params, batch, cfg)
    return loss, metrics


def compress_int8(g):
    """Symmetric int8 quantization (per-tensor scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def grads_roundtrip_int8(grads):
    """Quantize→dequantize grads (models compressed DP all-reduce)."""
    def rt(g):
        q, s = compress_int8(g.astype(jnp.float32))
        return decompress_int8(q, s).astype(g.dtype)
    return jax.tree.map(rt, grads)


def make_train_step(model_cfg, train_cfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    grad_fn = jax.value_and_grad(_loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch, model_cfg)
        return loss, metrics, grads

    def accumulate(params, batch):
        n = train_cfg.accum_steps
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % n == 0, (B, n)
        mb = B // n
        sliced = jax.tree.map(lambda x: x.reshape((n, mb) + x.shape[1:]), batch)

        def body(carry, micro):
            loss_acc, grads_acc = carry
            loss, _, grads = single(params, micro)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n, grads_acc, grads)
            return (loss_acc + loss / n, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), sliced)
        return loss, {"nll": loss}, grads

    def train_step(params, opt_state, batch):
        if train_cfg.accum_steps > 1:
            loss, metrics, grads = accumulate(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        if train_cfg.compress_grads:
            grads = grads_roundtrip_int8(grads)
        params, opt_state, opt_metrics = adamw_update(
            train_cfg.opt, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
