import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, print memory/cost analysis, and dump roofline raw terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k [--multi-pod] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, SHAPES, cell_is_active, get_arch, get_shape
from repro.distributed import sharding as shd
from repro.launch.input_specs import batch_specs, cache_specs
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models import transformer as tfm
from repro.training.optimizer import opt_state_pspecs, opt_state_specs
from repro.training.train_step import TrainConfig, make_train_step

from repro.launch import hlo_cost


def _mem_analysis_dict(ma) -> dict:
    keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes"]
    d = {}
    for k in keys:
        try:
            d[k] = int(getattr(ma, k))
        except Exception:
            pass
    return d


def build_cell(arch_name: str, shape_name: str, mesh, *, accum_steps=None,
               weight_stationary: bool = False, expert_parallel: bool = False):
    """Returns (fn, args_specs, in_shardings, out_shardings)."""
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    model = Model(cfg)
    phase = shape.phase
    shard_batch = shape.global_batch > 1
    rules = shd.rules_for(mesh, phase, shard_batch=shard_batch,
                          weight_stationary=weight_stationary and phase == "decode",
                          expert_parallel=expert_parallel)

    p_pspecs = shd.param_pspecs(model.param_axes(), rules)
    p_specs = model.param_specs()
    b_pspecs = shd.batch_pspecs(cfg, rules, phase)
    b_specs = batch_specs(cfg, shape)

    if phase == "train":
        if accum_steps is None:
            # microbatch so per-device activation transients fit 16GB HBM:
            # target ~16k tokens × 2k width per microbatch per device
            data_shards = 32 if "pod" in mesh.axis_names else 16
            tokens_local = shape.global_batch * shape.seq_len // data_shards
            est = tokens_local * cfg.d_model / (16384 * 2048)
            accum_steps = 1
            max_accum = shape.global_batch // data_shards
            while accum_steps < min(max_accum, est):
                accum_steps *= 2
        tcfg = TrainConfig(accum_steps=accum_steps)
        step = make_train_step(cfg, tcfg)
        o_specs = opt_state_specs(p_specs)
        o_pspecs = opt_state_pspecs(p_pspecs)

        def fn(params, opt_state, batch):
            with shd.use_rules(rules):
                return step(params, opt_state, batch)

        args = (p_specs, o_specs, b_specs)
        in_sh = (p_pspecs, o_pspecs, b_pspecs)
        out_sh = (p_pspecs, o_pspecs, None)
        return fn, args, in_sh, out_sh, cfg, shape

    c_specs = cache_specs(cfg, shape)
    c_pspecs = shd.cache_pspecs(cfg, rules)
    if phase == "prefill":
        def fn(params, batch, cache):
            with shd.use_rules(rules):
                return tfm.prefill(params, batch, cfg, cache)
        args = (p_specs, b_specs, c_specs)
        in_sh = (p_pspecs, b_pspecs, c_pspecs)
        out_sh = (None, c_pspecs)
        return fn, args, in_sh, out_sh, cfg, shape

    def fn(params, batch, cache, cache_len):
        with shd.use_rules(rules):
            return tfm.decode_step(params, batch, cfg, cache, cache_len)
    args = (p_specs, b_specs, c_specs, jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (p_pspecs, b_pspecs, c_pspecs, P())
    out_sh = (None, c_pspecs)
    return fn, args, in_sh, out_sh, cfg, shape


def dryrun_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
                verbose: bool = True, accum_steps=None,
                weight_stationary: bool = False,
                expert_parallel: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_sh, out_sh, cfg, shape = build_cell(
        arch_name, shape_name, mesh, accum_steps=accum_steps,
        weight_stationary=weight_stationary, expert_parallel=expert_parallel)

    def to_named(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
            is_leaf=lambda x: isinstance(x, P))

    with mesh:
        jitted = jax.jit(fn, in_shardings=to_named(in_sh),
                         out_shardings=to_named(out_sh))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)      # trip-count-aware (see hlo_cost.py)
    # persist the compiled HLO so the roofline can be re-derived without
    # recompiling (zstd: ~2MB text -> ~100KB)
    try:
        import zstandard
        os.makedirs("results/hlo", exist_ok=True)
        tag = f"{arch_name}_{shape_name}_{'mp' if multi_pod else 'sp'}"
        with open(f"results/hlo/{tag}.hlo.zst", "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception:
        pass
    res = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "phase": shape.phase,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": _mem_analysis_dict(ma),
        "xla_flops_once": float(ca.get("flops", -1)),   # raw (whiles counted once)
        "flops": cost.flops,                             # per-device, trip-aware
        "bytes_accessed": cost.bytes_accessed,
        "bytes_min": cost.bytes_min,
        "collectives": {"total_bytes": cost.collective_bytes,
                        "bytes": cost.collective_bytes_by_op,
                        "counts": cost.collective_counts},
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "hlo_lines": hlo.count("\n"),
    }
    if verbose:
        dev = res["devices"]
        mem = res["memory"]
        print(f"[dryrun] {arch_name} × {shape_name} on {res['mesh']}: "
              f"compile={t_compile:.0f}s flops/dev={res['flops']:.3e} "
              f"bytes/dev={res['bytes_accessed']:.3e} "
              f"coll/dev={cost.collective_bytes:.3e}B "
              f"arg={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB/dev "
              f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.2f}GB/dev", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  collectives: {res['collectives']}", flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=None)
    ap.add_argument("--weight-stationary", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for cfg in ARCHS.values():
            for shape in SHAPES.values():
                active, why = cell_is_active(cfg, shape)
                if active:
                    cells.append((cfg.name, shape.name))
                else:
                    print(f"[skip] {cfg.name} × {shape.name}: {why}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    for arch, shape in cells:
        try:
            results.append(dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                                       accum_steps=args.accum_steps,
                                       weight_stationary=args.weight_stationary,
                                       expert_parallel=args.expert_parallel))
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            traceback.print_exc()
            failures.append({"arch": arch, "shape": shape, "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"[dryrun] done: {len(results)} ok, {len(failures)} failed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
