"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod slice, 256 chips) or 2×16×16 (two pods, 512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the real local device (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
