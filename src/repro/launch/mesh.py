"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod slice, 256 chips) or 2×16×16 (two pods, 512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the real local device (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_test_mesh(shape=(2, 4), axis_names=None):
    """Small explicit-shape mesh for tests and CPU benchmarks.

    ``make_production_mesh`` hard-codes pod slices (256/512 chips) that can
    never instantiate on a test host; tests build meshes through this helper
    instead, under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    Axis names default to the production convention, rightmost-aligned:
    2 axes → ("data", "model"), 3 axes → ("pod", "data", "model").
    """
    if axis_names is None:
        axis_names = ("pod", "data", "model")[-len(shape):]
    need = math.prod(shape)
    have = jax.device_count()
    if have < need:
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {need} devices but the backend has "
            f"{have}; run under XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={need} (set BEFORE jax initializes)")
    return jax.make_mesh(tuple(shape), tuple(axis_names))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
