"""Trip-count-aware HLO cost analysis from ``compiled.as_text()``.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — with
scan-over-layers (and microbatch/flash scans) that undercounts FLOPs, bytes
and collectives by the trip count. This parser rebuilds the call graph from
the post-SPMD optimized HLO text, reads each while's trip count from its
``backend_config={"known_trip_count":...}`` annotation, and accumulates:

  * dot FLOPs        (2 · numel(result) · contracted-dim product, operand
                      shapes resolved through a module-wide symbol table)
  * bytes accessed   (operand + result bytes at fusion boundaries)
  * collective bytes (result-shape bytes per collective op)

each weighted by the product of enclosing loop trip counts. Validated in
``tests/test_hlo_cost.py`` against ``cost_analysis()`` on unrolled graphs and
against scan == unroll equivalence.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0, "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9\[\],{}\s/_*]*?\)?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_numel(type_str: str) -> int:
    dims = _first_shape_dims(type_str)
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str                      # text after the opening '(' of operands
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_entry: bool = False


def _split_operands(rest: str) -> Tuple[str, str]:
    """Split 'a, b), attr=...' into operand part and attribute part."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_module(hlo: str):
    comps: Dict[str, Computation] = {}
    symbols: Dict[str, str] = {}     # instr name -> result type
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw).strip()   # strip /*index=N*/ comments
        if cur is None:
            if line.endswith("{") and "->" in line:
                m = _HEADER_RE.match(line)
                if m:
                    cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.group(1), m.group(2).strip(), m.group(3), m.group(4)
        opnds_str, _ = _split_operands(rest)
        operands = re.findall(r"%([\w.\-]+)", opnds_str)
        ins = Instr(name, rtype, opcode, rest, operands)
        cur.instrs.append(ins)
        symbols[name] = rtype
    return comps, symbols


def _called_comps(instr: Instr) -> List[Tuple[str, str]]:
    out = []
    for key in ("condition", "body", "calls", "to_apply", "branch_computations",
                "true_computation", "false_computation"):
        for m in re.finditer(key + r"=\{?%?([\w.\-]+(?:, *%?[\w.\-]+)*)\}?", instr.rest):
            for name in re.split(r",\s*%?", m.group(1)):
                out.append((name.lstrip("%"), key))
    return out


def _while_trip_count(instr: Instr, comps) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.rest)
    if m:
        return int(m.group(1))
    # fallback: `counter < constant(N)` in the condition computation
    for name, role in _called_comps(instr):
        if role != "condition" or name not in comps:
            continue
        for ins in comps[name].instrs:
            if ins.opcode == "constant" and ins.result_type.startswith(("s32", "u32")):
                mm = re.match(r"\s*(\d+)\s*\)", ins.rest)
                if mm:
                    return int(mm.group(1))
    return 1


_ELEMENTWISE = {
    "exponential", "log", "tanh", "rsqrt", "sqrt", "divide", "power", "add",
    "subtract", "multiply", "maximum", "minimum", "negate", "abs", "cosine",
    "sine", "logistic", "expm1", "log1p", "floor", "ceil", "round",
}

_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "while", "conditional", "call",
             "custom-call", "copy-start", "copy-done", "async-start",
             "async-done", "add-dependency", "opt-barrier"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# Ops that genuinely move data through HBM even under aggressive fusion —
# used for the fusion-optimistic traffic bound ``bytes_min``. The CPU backend
# wraps almost every elementwise op in its own kLoop fusion, so boundary
# accounting (``bytes_accessed``) is a strong over-estimate of what the TPU
# compiler (which fuses whole chains) would do; the pair brackets reality.
# Dots/convs count wherever they appear (MXU reads operands from HBM/VMEM);
# data-movement ops count only at top level (inside fusions they fold into
# the producing/consuming kernel's single pass).
_MOVERS_ALWAYS = {"dot", "convolution",
                  "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute"}
_MOVERS_TOP = {"copy", "dynamic-slice", "dynamic-update-slice", "gather",
               "scatter", "sort"}


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_min: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "bytes_min": self.bytes_min,
            "collective_bytes": self.collective_bytes,
            "collective_counts": dict(self.collective_counts),
            "collective_bytes_by_op": dict(self.collective_bytes_by_op),
        }


def analyze(hlo: str) -> CostSummary:
    comps, symbols = parse_module(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    s = CostSummary()

    def dot_flops(ins: Instr) -> int:
        result_numel = _shape_numel(ins.result_type)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        if not m or not ins.operands:
            return 2 * result_numel
        lhs_dims = _first_shape_dims(symbols.get(ins.operands[0], ""))
        k = 1
        for ci in m.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                k *= lhs_dims[int(ci)]
        return 2 * result_numel * k

    def operand_bytes(ins: Instr) -> int:
        return sum(_shape_bytes(symbols.get(o, "")) for o in ins.operands)

    def visit(comp: Computation, mult: float, in_fusion: bool):
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                trip = _while_trip_count(ins, comps)
                for name, role in _called_comps(ins):
                    if name not in comps:
                        continue
                    visit(comps[name], mult * (trip if role == "body" else trip + 1),
                          in_fusion)
                continue
            if op == "fusion":
                for name, _ in _called_comps(ins):
                    if name in comps:
                        visit(comps[name], mult, True)
                # bytes at the fusion boundary
                if not in_fusion:
                    s.bytes_accessed += mult * (_shape_bytes(ins.result_type)
                                                + operand_bytes(ins))
                continue
            if op in ("call", "conditional"):
                for name, _ in _called_comps(ins):
                    if name in comps:
                        visit(comps[name], mult, in_fusion)
                continue

            # ---- flops -----------------------------------------------------
            if op == "dot":
                s.flops += mult * dot_flops(ins)
            elif op == "convolution":
                s.flops += mult * 2 * _shape_numel(ins.result_type)
            elif op in _ELEMENTWISE:
                s.flops += mult * _shape_numel(ins.result_type)
            elif op in ("reduce", "reduce-window"):
                s.flops += mult * max(_shape_numel(ins.result_type),
                                      operand_bytes(ins) // 4)

            # ---- collectives -----------------------------------------------
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                nbytes = mult * _shape_bytes(ins.result_type)
                s.collective_bytes += nbytes
                s.collective_bytes_by_op[base] = s.collective_bytes_by_op.get(base, 0) + nbytes
                s.collective_counts[base] = s.collective_counts.get(base, 0) + mult

            # ---- bytes ------------------------------------------------------
            if not in_fusion and op not in _NO_BYTES:
                s.bytes_accessed += mult * (_shape_bytes(ins.result_type)
                                            + operand_bytes(ins))
            if op in _MOVERS_ALWAYS or (not in_fusion and op in _MOVERS_TOP):
                s.bytes_min += mult * (_shape_bytes(ins.result_type)
                                       + operand_bytes(ins))

    visit(entry, 1.0, False)
    return s
