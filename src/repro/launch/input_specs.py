"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation — the same pattern shannon/kernels uses: weak-type
correct, shardable, consumed by ``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Model-input ShapeDtypeStructs for one cell."""
    B = shape.global_batch
    S = shape.seq_len if shape.phase != "decode" else 1
    i32 = jnp.int32
    specs = {"positions": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.modality == "audio_frames":
        specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if shape.phase == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """KV/state cache ShapeDtypeStructs (decode/prefill phases only)."""
    if shape.phase == "train":
        return None
    return tfm.cache_spec(cfg, shape.global_batch, shape.seq_len)


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Full argument spec set for the cell's entry point."""
    out = {"batch": batch_specs(cfg, shape)}
    cache = cache_specs(cfg, shape)
    if cache is not None:
        out["cache"] = cache
    if shape.phase == "decode":
        out["cache_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
