"""Research Paper Summarization application (§4.1).

Two MCP servers — ArXiv (download) and RAG (section summarization) — plus the
deterministic oracle rules that drive the ReAct agents for this app. Session:
  Q1: Summarize the introduction and core contributions of the paper titled <T>
  Q2: Describe its methodology and analysis
  Q3: Summarize its conclusions, implications and future work
"""
from __future__ import annotations

import json
import re
from typing import Dict, List

from repro.apps import data
from repro.apps.common import (AppSpec, extract_plan, memory_prompt_active,
                               parse_tool_messages, user_request_of, visible)
from repro.core.llm import ScriptedOracle
from repro.core.mcp import FastMCP

PAPERS_BUCKET = "fame-papers"

# ---------------------------------------------------------------------------
# MCP servers (the developer-facing FastMCP modules FAME wraps)
# ---------------------------------------------------------------------------

ARXIV_SOURCE = '''\
from repro.core.mcp import FastMCP

mcp = FastMCP("arxiv", memory_mb=128)
ARXIV_API = "https://export.arxiv.org/api"

@mcp.tool(description="Search arXiv for a paper by (partial) title")
@fame.wrapper()
def search_paper(title: str, ctx=None):
    ...

@mcp.tool(description="Download a paper PDF by title; returns extracted text")
@fame.wrapper()
async def download_paper(title: str, ctx=None):
    ...
'''

RAG_SOURCE = '''\
from repro.core.mcp import FastMCP

mcp = FastMCP("rag", memory_mb=400)

@mcp.tool(description="Summarize sections of a document matching a query")
@fame.wrapper()
def summarize_text(query: str, text: str, ctx=None):
    ...

@mcp.tool(description="Answer a question over a document")
@fame.wrapper()
def query_document(query: str, text: str, ctx=None):
    ...

@mcp.tool(description="Extract a named section from a document")
@fame.wrapper()
def extract_sections(text: str, section: str, ctx=None):
    ...
'''


def build_servers() -> List[FastMCP]:
    arxiv = FastMCP("arxiv", memory_mb=128)
    rag = FastMCP("rag", memory_mb=400)

    @arxiv.tool(description="Search arXiv for a paper by (partial) title",
                base_latency_s=0.6)
    def search_paper(title: str, ctx=None):
        pid = data.pid_by_title(title)
        return {"paper_id": pid, "title": data.title_of(pid),
                "pdf_mb": data.PAPERS[pid]["pdf_mb"]}

    @arxiv.tool(description="Download a paper PDF by title; returns extracted text",
                base_latency_s=2.0, per_kb_s=0.030)
    def download_paper(title: str, ctx=None):
        pid = data.pid_by_title(title)        # raises on hallucinated titles
        content = data.paper_content(pid)
        if ctx is not None and ctx.config.s3_files:
            url = ctx.objects.stash(PAPERS_BUCKET, f"{pid}.txt", content,
                                    title=data.title_of(pid))
            return (f"Downloaded '{data.title_of(pid)}' ({len(content)} chars). "
                    f"s3_url={url}")
        return f"Downloaded '{data.title_of(pid)}'.\nCONTENT:\n{content}"

    def _resolve_text(text: str, ctx):
        if text.startswith("s3://") and ctx is not None:
            fetched = ctx.objects.fetch_text(text)
            return fetched or ""
        return text

    def _summarize(query: str, text: str, ctx) -> str:
        doc = _resolve_text(text, ctx)
        sections = re.findall(r"== (\w[\w ]*) ==", doc)
        wanted = [s for s in sections
                  if any(w.lower() in s.lower() for w in query.split())] or sections[:2]
        body = " ".join(
            f"The {s} establishes {doc[200 + 97 * i:360 + 97 * i].strip()}."
            for i, s in enumerate(dict.fromkeys(wanted)))
        return f"SUMMARY ({query}): {body[:1100]}"

    @rag.tool(description="Summarize sections of a document matching a query",
              base_latency_s=0.8, per_kb_s=0.045)
    def summarize_text(query: str, text: str, ctx=None):
        return _summarize(query, text, ctx)

    @rag.tool(description="Answer a question over a document",
              base_latency_s=0.8, per_kb_s=0.045)
    def query_document(query: str, text: str, ctx=None):
        return _summarize(query, text, ctx)

    @rag.tool(description="Extract a named section from a document",
              base_latency_s=0.5, per_kb_s=0.02)
    def extract_sections(text: str, section: str, ctx=None):
        doc = _resolve_text(text, ctx)
        m = re.search(rf"== {re.escape(section)} ==\n(.*?)(?===|\Z)", doc, re.S)
        return f"SECTION {section}: {(m.group(1)[:800] if m else 'not found')}"

    return [arxiv, rag]


# ---------------------------------------------------------------------------
# Queries (three per session)
# ---------------------------------------------------------------------------


def queries(pid: str) -> List[str]:
    return [
        f"Summarize the introduction and core contributions of the paper "
        f"titled '{data.title_of(pid)}'",
        "Describe its methodology and analysis",
        "Summarize its conclusions, implications and future work",
    ]


_QUERY_SECTIONS = {
    "introduction": "Introduction Contributions",
    "methodology": "Methodology Analysis",
    "conclusions": "Conclusions Implications Future",
}


def _query_kind(q: str) -> str:
    ql = q.lower()
    for k in _QUERY_SECTIONS:
        if k in ql:
            return k
    return "introduction"


def _resolve_title(context: str):
    m = re.findall(r"titled '([^']+)'", context)
    if m:
        return m[-1]
    m = re.findall(r"Downloaded '([^']+)'", context)
    if m:
        return m[-1]
    return None


# ---------------------------------------------------------------------------
# Oracle rules
# ---------------------------------------------------------------------------


def build_oracles(**kw) -> Dict[str, ScriptedOracle]:
    planner, actor, evaluator = ScriptedOracle(name="planner"), \
        ScriptedOracle(name="actor"), ScriptedOracle(name="evaluator")

    # ---- Planner ---------------------------------------------------------
    def is_rs_planner(system, context):
        return "planner agent" in system and (
            "paper" in user_request_of(context).lower()
            or "its " in user_request_of(context).lower())

    def plan_rs(system, context, oracle):
        q = user_request_of(context)
        title = _resolve_title(context) or "UNKNOWN-PAPER"
        kind = _query_kind(q)
        steps = [
            {"tool": "download_paper", "arguments": {"title": title}},
            {"tool": "summarize_text",
             "arguments": {"query": f"Summarize {_QUERY_SECTIONS[kind]}",
                           "text": "$DOC"}},
        ]
        return json.dumps({"tools_to_use": steps,
                           "reasoning": f"Retrieve the paper '{title}' via the "
                                        f"arxiv MCP tool, then generate the "
                                        f"{kind} summary with the RAG tool."})

    planner.add_rule(is_rs_planner, plan_rs)

    # ---- Actor ------------------------------------------------------------
    def is_rs_actor(system, context):
        plan = extract_plan(system)
        tools = [s.get("tool") for s in plan.get("tools_to_use", [])]
        return "download_paper" in tools or "summarize_text" in tools

    def act_rs(system, context, oracle):
        plan = extract_plan(system)
        msgs = parse_tool_messages(context)
        allow_memory = memory_prompt_active(system)
        doc_ref = None
        for step in plan.get("tools_to_use", []):
            tool, args = step["tool"], dict(step.get("arguments", {}))
            if tool == "download_paper":
                prior = visible(msgs, "download_paper", allow_memory=allow_memory,
                                match=lambda a: a.get("title") == args["title"])
                if prior is not None and prior.content.startswith("ERROR"):
                    if not prior.from_memory:
                        # this run's download failed — surface the failure
                        return json.dumps({"final": f"ERROR: download failed "
                                           f"for title '{args['title']}'"})
                    prior = None                     # stale memory failure
                if prior is not None:
                    doc_ref = _doc_ref_from(prior.content)
                    continue
                return json.dumps({"tool_calls": [
                    {"tool": "download_paper", "arguments": args}]})
            if tool == "summarize_text":
                if doc_ref is None:
                    dl = visible(msgs, "download_paper", allow_memory=allow_memory)
                    if dl is None or dl.content.startswith("ERROR"):
                        return json.dumps(
                            {"final": "ERROR: no document available to summarize"})
                    doc_ref = _doc_ref_from(dl.content)
                args["text"] = doc_ref
                prior = visible(
                    msgs, "summarize_text", allow_memory=allow_memory,
                    match=lambda a: a.get("query") == args["query"])
                if prior is not None:
                    continue
                return json.dumps({"tool_calls": [
                    {"tool": "summarize_text", "arguments": args}]})
        # all steps satisfied -> final answer from the newest summary
        summ = visible(msgs, "summarize_text", allow_memory=allow_memory)
        body = summ.content if summ else "no summary produced"
        return json.dumps({"final": f"Here is the Summary: {body[:1200]}"})

    actor.add_rule(is_rs_actor, act_rs)

    # ---- Evaluator ---------------------------------------------------------
    def is_rs_eval(system, context):
        return "Evaluate if this action" in system

    def eval_rs(system, context, oracle):
        m = re.search(r"- Result: (.*?)\n- Current Iteration: (\d+)/(\d+)",
                      system, re.S)
        result = m.group(1) if m else ""
        iteration, max_iter = (int(m.group(2)), int(m.group(3))) if m else (1, 3)
        failed = ("ERROR" in result) or ("SUMMARY" not in result) or not result.strip()
        if not failed:
            return json.dumps({"success": True, "needs_retry": False,
                               "reason": "summary produced for requested sections"})
        return json.dumps({
            "success": False, "needs_retry": iteration < max_iter,
            "reason": "tool execution failed or produced no summary",
            "feedback": "The download failed — verify the exact paper title and "
                        "pass the document content to summarize_text."})

    evaluator.add_rule(is_rs_eval, eval_rs)
    return {"planner": planner, "actor": actor, "evaluator": evaluator}


def _doc_ref_from(content: str) -> str:
    m = re.search(r"s3_url=(\S+)", content)
    if m:
        return m.group(1)
    m = re.search(r"CONTENT:\n(.*)", content, re.S)
    return m.group(1) if m else content


APP = AppSpec(name="research_summary", servers=[], sources={
    "arxiv": ARXIV_SOURCE, "rag": RAG_SOURCE},
    inputs=["P1", "P2", "P3"], queries=queries, build_oracles=build_oracles)
APP.servers = build_servers()
