"""Shared app plumbing: oracle parsing helpers + AppSpec."""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.mcp import FastMCP

_MARKERS = (r"\n\[ToolMessage |\n\[planner\]|\n\[actor\]|\n\[user\]|\n\[tool\]"
            r"|\n\[assistant\]|\n\[MESSAGES\]|\n\[USER REQUEST\]"
            r"|\n\[EVALUATOR FEEDBACK\]|\n--- invocation|\nfinal:|\Z")
TOOLMSG_RE = re.compile(
    r"\[ToolMessage tool=(\S+) args=(\{.*?\})\]\n(.*?)(?=" + _MARKERS + ")", re.S)


@dataclasses.dataclass
class ToolMsg:
    tool: str
    args: dict
    content: str
    from_memory: bool


def parse_tool_messages(context: str) -> List[ToolMsg]:
    """All visible ToolMessages; flags whether each came from injected memory
    (before the [MESSAGES] section) or the current conversation."""
    idx_msgs = context.find("[MESSAGES]")
    out = []
    for m in TOOLMSG_RE.finditer(context):
        try:
            args = json.loads(m.group(2))
        except json.JSONDecodeError:
            args = {}
        out.append(ToolMsg(m.group(1), args, m.group(3).strip(),
                           from_memory=(idx_msgs < 0 or m.start() < idx_msgs)))
    return out


def visible(msgs: List[ToolMsg], tool: str, *, allow_memory: bool,
            match: Optional[Callable[[dict], bool]] = None) -> Optional[ToolMsg]:
    """Newest visible ToolMessage for `tool` (memory ones only if allowed)."""
    for m in reversed(msgs):
        if m.tool != tool:
            continue
        if m.from_memory and not allow_memory:
            continue
        if match is not None and not match(m.args):
            continue
        return m
    return None


def extract_plan(system: str) -> dict:
    m = re.search(r"- Plan: (\{.*?\})\nExecute", system, re.S)
    if not m:
        m = re.search(r"- Plan: (\{.*\})", system, re.S)
    if not m:
        return {}
    try:
        return json.loads(m.group(1))
    except json.JSONDecodeError:
        return {}


def memory_prompt_active(system: str) -> bool:
    return "Check previous ToolMessage responses" in system


def user_request_of(context: str) -> str:
    m = re.search(r"\[USER REQUEST\]\n(.*?)(?:\n\n|\n\[|\Z)", context, re.S)
    return m.group(1).strip() if m else ""


@dataclasses.dataclass
class AppSpec:
    name: str
    servers: List[FastMCP]
    sources: Dict[str, str]                      # server name -> server.py source
    inputs: List[str]                            # P1..P3 / L1..L3
    queries: Callable[[str], List[str]]          # input id -> 3 session queries
    build_oracles: Callable[..., Dict[str, Any]]  # -> {"planner":.., "actor":.., "evaluator":..}
