"""Log Analytics application (§4.1).

Three MCP servers — Log Analyzer, Calculator, Visualization — plus oracle
rules. Session (per log file):
  Q1: Count the occurrences of error states <STATE> in the log file <FILE>
  Q2: Find the mean and standard deviation of timestamps for the most frequent error
  Q3: Find the min/max/mean/median timestamps with visualization and comparison
      between error states
"""
from __future__ import annotations

import json
import re
import statistics
from typing import Dict, List

from repro.apps import data
from repro.apps.common import (AppSpec, extract_plan, memory_prompt_active,
                               parse_tool_messages, user_request_of, visible)
from repro.core.llm import ScriptedOracle
from repro.core.mcp import FastMCP

TS_BUCKET = "fame-timestamps"
PLOTS_BUCKET = "fame-plots"

LOG_SOURCE = '''\
from repro.core.mcp import FastMCP

mcp = FastMCP("log_analyzer", memory_mb=200)

@mcp.tool(description="List error types with counts in a log file")
@fame.wrapper()
def list_error_types(file: str, ctx=None):
    ...

@mcp.tool(description="Extract timestamps of lines matching a keyword")
@fame.wrapper()
def filter_by_keyword(file: str, keyword: str, ctx=None):
    ...

@mcp.tool(description="Count occurrences of a keyword in a log file")
@fame.wrapper()
def count_occurrences(file: str, keyword: str, ctx=None):
    ...

@mcp.tool(description="Return the raw log file content")
@fame.wrapper()
def read_log(file: str, ctx=None):
    ...
'''

CALC_SOURCE = '''\
from repro.core.mcp import FastMCP

mcp = FastMCP("calculator", memory_mb=400)

@mcp.tool()
@fame.wrapper()
def min_list(values, ctx=None): ...

@mcp.tool()
@fame.wrapper()
def max_list(values, ctx=None): ...

@mcp.tool()
@fame.wrapper()
def mean(values, ctx=None): ...

@mcp.tool()
@fame.wrapper()
def median(values, ctx=None): ...

@mcp.tool()
@fame.wrapper()
def std(values, ctx=None): ...
'''

VIZ_SOURCE = '''\
from repro.core.mcp import FastMCP

mcp = FastMCP("visualization", memory_mb=400)

@mcp.tool(description="Render a bar chart; returns an S3 PNG path")
@fame.wrapper()
def bar_chart(data, title: str = "", ctx=None): ...

@mcp.tool(description="Render a line plot; returns an S3 PNG path")
@fame.wrapper()
def line_plot(data, title: str = "", ctx=None): ...

@mcp.tool(description="Render a scatter plot; returns an S3 PNG path")
@fame.wrapper()
def scatter_plot(data, title: str = "", ctx=None): ...
'''


def _resolve_values(values, ctx) -> List[float]:
    if isinstance(values, str) and values.startswith("s3://") and ctx is not None:
        text = ctx.objects.fetch_text(values) or "[]"
        return json.loads(text)
    if isinstance(values, str):
        return json.loads(values)
    return list(values)


def build_servers() -> List[FastMCP]:
    logs = FastMCP("log_analyzer", memory_mb=200)
    calc = FastMCP("calculator", memory_mb=400)
    viz = FastMCP("visualization", memory_mb=400)

    @logs.tool(description="List error types with counts in a log file",
               base_latency_s=0.4, per_kb_s=0.004)
    def list_error_types(file: str, ctx=None):
        lid = data.lid_by_path(file)             # raises on unknown path
        return json.dumps(data.LOGS[lid]["errors"])

    @logs.tool(description="Extract timestamps of lines matching a keyword",
               base_latency_s=0.5, per_kb_s=0.004)
    def filter_by_keyword(file: str, keyword: str, ctx=None):
        lid = data.lid_by_path(file)
        ts = [l.ts for l in data.log_lines(lid) if l.error == keyword]
        if not ts:
            return f"ERROR: no lines matching {keyword!r}"
        payload = json.dumps(ts)
        if ctx is not None and ctx.config.s3_files:
            url = ctx.objects.stash(TS_BUCKET, f"{lid}-{keyword}.json", payload)
            return f"Found {len(ts)} timestamps for {keyword}. s3_url={url}"
        return f"Found {len(ts)} timestamps for {keyword}.\nTIMESTAMPS:\n{payload}"

    @logs.tool(description="Count occurrences of a keyword in a log file",
               base_latency_s=0.4, per_kb_s=0.004)
    def count_occurrences(file: str, keyword: str, ctx=None):
        lid = data.lid_by_path(file)
        return f"count({keyword})={data.LOGS[lid]['errors'].get(keyword, 0)}"

    @logs.tool(description="Return the raw log file content",
               base_latency_s=0.6, per_kb_s=0.004)
    def read_log(file: str, ctx=None):
        lid = data.lid_by_path(file)
        text = data.log_text(lid)
        if ctx is not None and ctx.config.s3_files:
            url = ctx.objects.stash(TS_BUCKET, f"{lid}-raw.log", text)
            return f"Read {len(text)} bytes. s3_url={url}"
        return text

    def _calc(fn_name, fn):
        def tool(values, ctx=None):
            vals = _resolve_values(values, ctx)
            return f"{fn_name}={fn(vals):.3f}"
        tool.__name__ = fn_name
        return tool

    for fn_name, fn in [("min_list", min), ("max_list", max),
                        ("mean", statistics.fmean), ("median", statistics.median),
                        ("std", lambda v: statistics.pstdev(v))]:
        calc.tool(description=f"{fn_name} of a list of numbers",
                  base_latency_s=0.05)(_calc(fn_name, fn))

    def _plot(kind):
        def tool(data, title: str = "", ctx=None):
            vals = _resolve_values(data, ctx) if data else []
            png = f"PNG:{kind}:{title}:{len(vals)}points".encode()
            if ctx is not None:
                import hashlib
                tag = hashlib.sha1(f"{kind}{title}".encode()).hexdigest()[:8]
                url = ctx.objects.put(PLOTS_BUCKET, f"{kind}-{tag}.png", png)
                return f"PLOT saved: {url} ({kind}, {len(vals)} points)"
            return f"PLOT rendered in-line ({kind}, {len(vals)} points)"
        tool.__name__ = kind
        return tool

    for kind in ("bar_chart", "line_plot", "scatter_plot"):
        viz.tool(description=f"Render a {kind.replace('_', ' ')}; returns an S3 PNG path",
                 base_latency_s=0.7)(_plot(kind))

    return [logs, calc, viz]


def queries(lid: str) -> List[str]:
    meta = data.LOGS[lid]
    state = sorted(meta["errors"])[0]
    return [
        f"Count the occurrences of error states '{state}' in the log file "
        f"'{meta['path']}'",
        "Find the mean and standard deviation of timestamps for the most "
        "frequent error",
        "Find the min/max/mean/median timestamps with visualization and "
        "comparison between error states",
    ]


def _resolve_file(context: str):
    m = re.findall(r"log file '([^']+)'", context)
    if m:
        return m[-1]
    m = re.findall(r"\"file\": \"([^\"]+)\"", context)
    return m[-1] if m else None


def _kind_of(q: str) -> str:
    ql = q.lower()
    if "count" in ql:
        return "count"
    if "standard deviation" in ql or "std" in ql:
        return "stats"
    return "full"


def build_oracles(**kw) -> Dict[str, ScriptedOracle]:
    planner, actor, evaluator = ScriptedOracle(name="planner"), \
        ScriptedOracle(name="actor"), ScriptedOracle(name="evaluator")

    # ---- Planner -----------------------------------------------------------
    def is_la_planner(system, context):
        q = user_request_of(context).lower()
        return "planner agent" in system and ("log" in q or "error" in q
                                              or "timestamps" in q)

    def plan_la(system, context, oracle):
        q = user_request_of(context)
        file = _resolve_file(context) or "UNKNOWN-FILE"
        kind = _kind_of(q)
        m = re.search(r"error states '([^']+)'", q)
        state = m.group(1) if m else "$TOP"
        if kind == "count":
            steps = [{"tool": "filter_by_keyword",
                      "arguments": {"file": file, "keyword": state}},
                     {"tool": "count_occurrences",
                      "arguments": {"file": file, "keyword": state}}]
        elif kind == "stats":
            steps = [{"tool": "list_error_types", "arguments": {"file": file}},
                     {"tool": "filter_by_keyword",
                      "arguments": {"file": file, "keyword": "$TOP"}},
                     {"tool": "mean", "arguments": {"values": "$TS"}},
                     {"tool": "std", "arguments": {"values": "$TS"}}]
        else:
            steps = [{"tool": "list_error_types", "arguments": {"file": file}},
                     {"tool": "filter_by_keyword",
                      "arguments": {"file": file, "keyword": "$TOP"}},
                     {"tool": "min_list", "arguments": {"values": "$TS"}},
                     {"tool": "max_list", "arguments": {"values": "$TS"}},
                     {"tool": "mean", "arguments": {"values": "$TS"}},
                     {"tool": "median", "arguments": {"values": "$TS"}},
                     {"tool": "line_plot",
                      "arguments": {"data": "$TS", "title": "error timeline"}}]
        return json.dumps({"tools_to_use": steps,
                           "reasoning": f"Analyze {file} for '{state}' via the log "
                                        f"analyzer, aggregate with the calculator"
                                        + (", then visualize" if kind == "full" else "")})

    planner.add_rule(is_la_planner, plan_la)

    # ---- Actor --------------------------------------------------------------
    def is_la_actor(system, context):
        plan = extract_plan(system)
        tools = {s.get("tool") for s in plan.get("tools_to_use", [])}
        return bool(tools & {"filter_by_keyword", "list_error_types", "read_log"})

    def act_la(system, context, oracle):
        plan = extract_plan(system)
        msgs = parse_tool_messages(context)
        allow_memory = memory_prompt_active(system)
        top_error, ts_ref = None, None
        results = []

        def fill(args):
            out = {}
            for k, v in args.items():
                if v == "$TOP":
                    out[k] = top_error or "UNKNOWN-ERROR"
                elif v == "$TS":
                    out[k] = ts_ref or "[]"
                else:
                    out[k] = v
            return out

        for step in plan.get("tools_to_use", []):
            tool = step["tool"]
            args = fill(step.get("arguments", {}))
            prior = visible(msgs, tool, allow_memory=allow_memory,
                            match=lambda a, want=args: all(
                                a.get(k) == v for k, v in want.items()))
            if prior is not None and prior.content.startswith("ERROR"):
                if not prior.from_memory:
                    return json.dumps({"final": f"ERROR: {tool} failed"})
                prior = None
            if prior is None:
                return json.dumps({"tool_calls": [
                    {"tool": tool, "arguments": args}]})
            # harvest placeholders from the satisfied step
            if tool == "list_error_types":
                counts = json.loads(prior.content)
                top_error = max(counts, key=counts.get)
            if tool == "filter_by_keyword":
                m = re.search(r"s3_url=(\S+)", prior.content)
                if m:
                    ts_ref = m.group(1)
                else:
                    m = re.search(r"TIMESTAMPS:\n(.*)", prior.content, re.S)
                    ts_ref = m.group(1).strip() if m else "[]"
            results.append(f"{tool}: {prior.content[:160]}")
        return json.dumps({"final": "ANALYTICS RESULT — " + " | ".join(results)})

    actor.add_rule(is_la_actor, act_la)

    # ---- Evaluator ------------------------------------------------------------
    def is_la_eval(system, context):
        return "Evaluate if this action" in system and (
            "filter_by_keyword" in system or "log" in system.lower())

    def eval_la(system, context, oracle):
        m = re.search(r"- Result: (.*?)\n- Current Iteration: (\d+)/(\d+)",
                      system, re.S)
        result = m.group(1) if m else ""
        iteration, max_iter = (int(m.group(2)), int(m.group(3))) if m else (1, 3)
        ok = "ANALYTICS RESULT" in result and "ERROR" not in result
        if ok:
            return json.dumps({"success": True, "needs_retry": False,
                               "reason": "aggregates computed for the requested log"})
        return json.dumps({
            "success": False, "needs_retry": iteration < max_iter,
            "reason": "analytics incomplete or a tool failed",
            "feedback": "Verify the log file path and error keyword; pass the "
                        "timestamp list (or its S3 URL) to the calculator tools."})

    evaluator.add_rule(is_la_eval, eval_la)
    return {"planner": planner, "actor": actor, "evaluator": evaluator}


APP = AppSpec(name="log_analytics", servers=[], sources={
    "log_analyzer": LOG_SOURCE, "calculator": CALC_SOURCE,
    "visualization": VIZ_SOURCE},
    inputs=["L1", "L2", "L3"], queries=queries, build_oracles=build_oracles)
APP.servers = build_servers()
