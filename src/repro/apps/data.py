"""Deterministic synthetic corpus for the two reference applications.

Paper instances mirror §4.1 (titles + PDF sizes); extracted-text sizes are
calibrated so config-N token counts land in the ranges Fig. 5 reports
(~4.7 KB text per MB of PDF). Log files mirror the LogHub samples.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Dict, List

# ---------------------------------------------------------------------------
# Research papers (P1–P3)
# ---------------------------------------------------------------------------

PAPERS = {
    "P1": {"title": "Multi-scale competition in the Majorana-Kondo system",
           "pdf_mb": 5.6},
    "P2": {"title": "Chondrule formation by collisions of planetesimals "
                    "containing volatiles triggered by Jupiter's formation",
           "pdf_mb": 2.1},
    "P3": {"title": "Resolving the flat-spectrum conundrum: clumpy aerosol "
                    "distributions in sub-Neptune atmospheres",
           "pdf_mb": 3.7},
}

_SECTIONS = ["Introduction", "Contributions", "Methodology", "Analysis",
             "Results", "Conclusions", "Implications", "Future Work"]


def _det_words(seed: str, n: int) -> str:
    rng = random.Random(int(hashlib.sha256(seed.encode()).hexdigest()[:8], 16))
    vocab = ["the", "system", "we", "observe", "scaling", "regime", "coupling",
             "measurement", "model", "spectral", "analysis", "parameter",
             "estimate", "distribution", "dynamics", "interaction", "phase",
             "signal", "response", "structure", "temperature", "formation"]
    return " ".join(rng.choice(vocab) for _ in range(n))


def paper_content(pid: str) -> str:
    """Deterministic 'extracted text' for a paper, sized from its PDF MB."""
    meta = PAPERS[pid]
    chars_target = int(meta["pdf_mb"] * 4_700)
    per_section = max(200, chars_target // (6 * len(_SECTIONS)))
    parts = [f"TITLE: {meta['title']}"]
    for sec in _SECTIONS:
        parts.append(f"\n== {sec} ==\n" + _det_words(pid + sec, per_section))
    text = "\n".join(parts)
    reps = max(1, chars_target // max(len(text), 1))
    return (text * reps)[:chars_target]


def title_of(pid: str) -> str:
    return PAPERS[pid]["title"]


def pid_by_title(title: str) -> str:
    for pid, meta in PAPERS.items():
        if meta["title"].lower() in title.lower() or title.lower() in meta["title"].lower():
            return pid
    raise KeyError(f"unknown paper title: {title!r}")


# ---------------------------------------------------------------------------
# Log files (L1–L3, LogHub-style)
# ---------------------------------------------------------------------------

LOGS = {
    "L1": {"path": "/logs/apache.log", "kind": "Apache", "kb": 170,
           "errors": {"AH01630": 214, "AH00558": 97, "AH00163": 41}},
    "L2": {"path": "/logs/hadoop.log", "kind": "Hadoop", "kb": 380,
           "errors": {"LeaseExpired": 331, "BlockMissing": 120, "DiskChecker": 58}},
    "L3": {"path": "/logs/openssh.log", "kind": "OpenSSH", "kb": 220,
           "errors": {"AuthFail": 402, "ConnReset": 154, "Timeout": 66}},
}


@dataclasses.dataclass
class LogLine:
    ts: float
    error: str
    text: str


def log_lines(lid: str) -> List[LogLine]:
    meta = LOGS[lid]
    rng = random.Random(int(hashlib.sha256(lid.encode()).hexdigest()[:8], 16))
    lines = []
    t = 1_700_000_000.0
    for error, count in meta["errors"].items():
        for i in range(count):
            t_i = t + rng.random() * 86_400
            lines.append(LogLine(round(t_i, 3), error,
                                 f"{t_i:.3f} [{meta['kind']}] ERROR {error} "
                                 f"worker={rng.randint(1, 64)} detail={_det_words(lid + error + str(i), 6)}"))
    lines.sort(key=lambda l: l.ts)
    return lines


def log_text(lid: str) -> str:
    body = "\n".join(l.text for l in log_lines(lid))
    target = LOGS[lid]["kb"] * 1024
    filler = "\n# heartbeat ok " + _det_words(lid + "hb", 8)
    while len(body) < target:
        body += filler
    return body[:target]


def lid_by_path(path: str) -> str:
    for lid, meta in LOGS.items():
        if meta["path"] == path or path.endswith(meta["path"].rsplit("/", 1)[-1]):
            return lid
    raise KeyError(f"unknown log path: {path!r}")


def most_frequent_error(lid: str) -> str:
    return max(LOGS[lid]["errors"].items(), key=lambda kv: kv[1])[0]
