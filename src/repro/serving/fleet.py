"""Replica fleet serving: N independent engines behind one front door.

One ``LLMServer`` + ``BackgroundPump`` is a single standing service — its
ceiling is one device's slot count. ``FleetServer`` is the FaaS-shaped next
rung (ROADMAP: "data-parallel replica groups behind one scheduler"): it
fronts N fully independent replicas — each its own ``LLMServer`` with its
own pump, cache pools and radix trie, optionally its own sub-mesh — behind
the same ``open_session`` / ``submit`` / ``stream`` / ``cancel`` surface,
so everything written against ``LLMServer`` (the FAME drivers in
fame/fusion.py included) runs against a fleet unchanged.

Placement (``ReplicaRouter``), in order:

1. **Prefix affinity** — every replica exports a cheap radix *keyspace
   digest*: the hashes of its trie's first-block edge labels
   (``RadixTree.keyspace_digest``). A new prompt whose leading
   ``page_size``-token block appears in a replica's digest lands there —
   where the shared pages / state snapshots already live — because agent
   traffic is prefix-heavy and a radix hit beats an idle replica's cold
   prefill. Digests are cached per replica with a short TTL so routing
   costs no pump round-trip on the hot path.
2. **Least-loaded EWMA fallback** — no digest hit (or dense mode): pick
   the replica minimizing (queued + running) × EWMA per-token decode
   service time (the PR-8 overload predictor), tie-broken by fewest
   placements so cold replicas spread instead of piling on replica 0.
3. **Overload spill** — a saturated replica (admission queue at its
   ``OverloadPolicy.max_queue_depth``) is skipped while any peer has
   headroom, and a typed ``OverloadError`` from the chosen replica retries
   the remaining candidates in load order — the fleet spills *before* a
   single replica sheds. Only when every replica refuses does the error
   propagate.

Sessions are **sticky**: a ``FleetSession`` pins to a replica at its first
turn (placed by that turn's prompt) and every later turn goes to the same
replica, where its retained tail state lives. Turn submissions never spill
— an ``OverloadError`` on a pinned replica propagates, like a single
server.

Failover: replica death (pump crash / stall-death — ``server.pumping``
goes False, waiters see ``PumpStalledError``) is detected on the next
routing decision (or an explicit ``check_health()``). The dead replica's
in-memory ``SessionJournal`` remains readable post-mortem, and every fleet
session pinned there is **migrated**: its journal entry is replayed onto a
healthy peer via the scheduler's ``restore_session`` — the same
token-level replay ``LLMServer.restore_sessions`` uses — so the next
turn's greedy output is bit-identical to an uninterrupted server. The
in-flight turn at crash time fails typed (the pump already terminated it);
completed turns survive. Elastic scale mirrors this: ``drain(i)``
quiesces a replica, migrates its sessions, and closes it;
``add_replica()`` brings a new engine online sharing the fleet's weights.

All replicas share one set of parameter arrays (reads only — nothing
donates weights), so an N-replica fleet costs N× cache/activation memory
but 1× weights, and greedy outputs are bit-identical across replicas.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Union

from repro.serving.faults import OverloadError, PumpStalledError
from repro.serving.journal import SessionJournal
from repro.serving.pump import PumpConfig
from repro.serving.scheduler import (EngineConfig, OverloadPolicy,
                                     SamplingParams)
from repro.serving.server import Handle, LLMServer, Session, StepOutcome
from repro.serving.tokenizer import ByteTokenizer

__all__ = ["FleetServer", "FleetSession", "ReplicaRouter"]


@dataclasses.dataclass(eq=False)       # identity semantics: usable in sets
class _Replica:
    """One engine behind the fleet front, plus its routing bookkeeping."""
    idx: int
    server: LLMServer
    pumped: bool                      # replicas built with a background pump
    draining: bool = False            # drain() in progress: no new placements
    failed: bool = False              # pump died; sessions migrated away
    removed: bool = False             # drained + closed (index stays stable)
    routed: int = 0                   # placements landed here (tie-break)
    digest: frozenset = frozenset()   # cached radix keyspace digest
    digest_t: float = -1.0            # monotonic time of the cached digest

    @property
    def healthy(self) -> bool:
        """Eligible for new placements and still able to serve."""
        if self.draining or self.failed or self.removed:
            return False
        return self.server.pumping if self.pumped else True


class ReplicaRouter:
    """Placement policy: prefix affinity, then least-loaded EWMA.

    Stateless beyond the per-replica digest cache it maintains (on the
    ``_Replica`` records); safe to call from many submitter threads — a
    racing double-refresh of one digest is harmless.
    """

    def __init__(self, page_size: int, digest_ttl_s: float = 0.25):
        self.page_size = page_size
        self.digest_ttl_s = digest_ttl_s

    def head_key(self, ids) -> Optional[int]:
        """Hash of the prompt's first radix block (``page_size`` tokens) —
        the unit the keyspace digest indexes. None when the prompt is
        shorter than one block (nothing shareable to route on)."""
        if ids is None or len(ids) < self.page_size:
            return None
        return hash(tuple(ids[:self.page_size]))

    def load(self, r: _Replica):
        return (r.server.load_score(), r.routed, r.idx)

    def digest_of(self, r: _Replica) -> frozenset:
        now = time.monotonic()
        if now - r.digest_t > self.digest_ttl_s:
            try:
                r.digest = r.server.radix_digest()
            except PumpStalledError:
                r.digest = frozenset()          # dying replica: no affinity
            r.digest_t = now
        return r.digest

    def order(self, cands: List[_Replica], ids
              ) -> "tuple[List[_Replica], set]":
        """Candidates in preference order + the affinity subset. Affinity
        matches (digest contains the prompt's first block) come first,
        each group sorted least-loaded."""
        key = self.head_key(ids)
        aff = [r for r in cands
               if key is not None and key in self.digest_of(r)]
        rest = [r for r in cands if r not in aff]
        aff.sort(key=self.load)
        rest.sort(key=self.load)
        return aff + rest, set(aff)


class FleetSession:
    """One multi-turn conversation on the fleet — same contract as
    ``server.Session``, plus replica stickiness and transparent migration.

    The session pins to a replica lazily at its FIRST turn (so placement
    can use that turn's prompt for affinity); every later turn is served by
    the pinned replica, whose retained tail state makes the turn a
    delta-prefill. If the pinned replica dies or drains, the next turn
    transparently lands on a healthy peer with the journaled conversation
    replayed (greedy-bit-identical continuation)."""

    def __init__(self, fleet: "FleetServer", sid: int):
        self._fleet = fleet
        self.sid = sid                      # fleet-level id (router-stable)
        self.closed = False
        self._replica: Optional[_Replica] = None
        self._sess: Optional[Session] = None   # underlying replica session

    @property
    def replica_index(self) -> Optional[int]:
        """Index of the pinned replica (None before the first turn)."""
        return self._replica.idx if self._replica is not None else None

    @property
    def text(self) -> str:
        return self._sess.text if self._sess is not None else ""

    @property
    def turns(self) -> int:
        return self._sess.turns if self._sess is not None else 0

    @property
    def busy(self) -> bool:
        return self._sess.busy if self._sess is not None else False

    def submit(self, prompt: str,
               params: Optional[SamplingParams] = None) -> Handle:
        if self.closed:
            raise RuntimeError(f"fleet session {self.sid} is closed")
        return self._fleet._submit_session(self, prompt, params, None)

    def close(self):
        """Release the pinned replica's retained tail state and forget the
        session fleet-wide. Safe on a dead replica (nothing to release —
        its device state died with it)."""
        if self.closed:
            return
        self.closed = True
        with self._fleet._lock:
            self._fleet._sessions.pop(self.sid, None)
        if (self._sess is not None and self._replica is not None
                and not self._replica.removed):
            try:
                self._sess.close()
            except PumpStalledError:
                pass                        # replica died underneath us


class FleetServer:
    """N independent ``LLMServer`` replicas behind one serving surface.

    Construction mirrors ``LLMServer`` (every per-engine knob is applied to
    each replica) plus ``num_replicas`` and optional ``meshes`` — a list of
    per-replica device meshes for sub-mesh tensor parallelism inside a
    data-parallel fleet. ``pump=True`` (default) gives every replica its
    own background pump; ``pump=False`` builds cooperative replicas driven
    by ``FleetServer.step()`` (single-threaded determinism for tests).

    Thread-safety matches a pumping ``LLMServer``: submit / session /
    cancel / stats may be called from any thread. The fleet lock guards
    only routing bookkeeping and the session map — never a pump round-trip
    on the submit hot path — so admission to different replicas proceeds
    concurrently.
    """

    def __init__(self, cfg, *, num_replicas: int = 2, num_slots: int = 4,
                 capacity: int = 512, params=None, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None,
                 retry=None, default_deadline_s: Optional[float] = None,
                 injector=None, watchdog_s: Optional[float] = None,
                 overload: Optional[OverloadPolicy] = None,
                 pump: Union[bool, PumpConfig, None] = True,
                 meshes: Optional[list] = None,
                 digest_ttl_s: float = 0.25):
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be >= 1, got {num_replicas}")
        if meshes is not None and len(meshes) != num_replicas:
            raise ValueError(f"meshes has {len(meshes)} entries for "
                             f"{num_replicas} replicas")
        self.cfg = cfg
        self._engine_cfg = engine_cfg or EngineConfig()
        self._server_kw = dict(num_slots=num_slots, capacity=capacity,
                               seed=seed, retry=retry,
                               default_deadline_s=default_deadline_s,
                               injector=injector, watchdog_s=watchdog_s,
                               overload=overload, pump=pump)
        self._pumped = bool(pump)
        self._lock = threading.RLock()
        self.router = ReplicaRouter(self._engine_cfg.page_size,
                                    digest_ttl_s=digest_ttl_s)
        self.tokenizer = ByteTokenizer(cfg.vocab_size)
        self._replicas: List[_Replica] = []
        self._sessions: Dict[int, FleetSession] = {}
        self._next_fsid = 0
        self._closed = False
        # fleet gauges (see stats())
        self._routed = 0
        self._affinity_hits = 0
        self._spilled = 0
        self._migrated = 0
        self._replicas_failed = 0
        self._replicas_drained = 0
        # replica 0 initializes the weights once; every peer shares the
        # same arrays (reads only) — 1× weight memory, bit-identical greedy
        first = self._make_server(params, meshes[0] if meshes else None)
        self._params = first.params
        self._replicas.append(_Replica(0, first, self._pumped))
        for i in range(1, num_replicas):
            srv = self._make_server(self._params,
                                    meshes[i] if meshes else None)
            self._replicas.append(_Replica(i, srv, self._pumped))

    def _make_server(self, params, mesh) -> LLMServer:
        ecfg = self._engine_cfg
        if mesh is not None:
            ecfg = dataclasses.replace(ecfg, mesh=mesh)
        return LLMServer(self.cfg, params=params, engine_cfg=ecfg,
                         **self._server_kw)

    # ---- introspection -----------------------------------------------------
    @property
    def params(self):
        return self._params

    @property
    def replicas(self) -> List[_Replica]:
        """The replica records (index-stable: removed replicas keep their
        slot, flagged ``removed``). Tests and benches reach through
        ``replicas[i].server`` for chaos injection."""
        return self._replicas

    @property
    def num_replicas(self) -> int:
        """Replicas currently able to take traffic."""
        return sum(1 for r in self._replicas if r.healthy)

    @property
    def pumping(self) -> bool:
        """True while any replica's background pump is alive — the FAME
        drivers key off this exactly as they do for one ``LLMServer``."""
        return any(r.pumped and r.server.pumping for r in self._replicas
                   if not r.removed)

    def stats(self) -> dict:
        """Fleet gauges + a curated cross-replica aggregate + every
        replica's own ``stats()`` under ``per_replica`` (None for removed
        slots). Counters sum; ``queue_age_max_s`` / ``ewma_decode_s_per_tok``
        take the max (a fleet is as slow as its slowest member)."""
        per = [None if r.removed else r.server.stats()
               for r in self._replicas]
        live = [p for p in per if p is not None]
        sum_keys = [
            "decode_tokens", "prompt_tokens", "prefix_hit_tokens",
            "queued_requests", "live_requests", "sessions_opened",
            "session_turns", "turn_prefix_hits", "cancelled_requests",
            "shed_requests", "preemptions", "preempt_resumes",
            "breaker_trips", "timed_out", "dead_lettered",
            "dispatch_retries", "admission_retries", "watchdog_stalls",
            "journaled_sessions", "stream_chunks", "grouped_admissions",
            "engine_steps", "pump_steps", "pump_stall_notices",
        ]
        with self._lock:
            out = {
                "fleet_replicas": self.num_replicas,
                "fleet_replicas_total": len(self._replicas),
                "replicas_failed": self._replicas_failed,
                "replicas_drained": self._replicas_drained,
                "routed_requests": self._routed,
                "affinity_hits": self._affinity_hits,
                "affinity_rate": self._affinity_hits / max(self._routed, 1),
                "spilled_admissions": self._spilled,
                "migrated_sessions": self._migrated,
                "fleet_sessions": len(self._sessions),
            }
        for k in sum_keys:
            out[k] = sum(p.get(k, 0) for p in live)
        out["queue_age_max_s"] = max(
            (p.get("queue_age_max_s", 0.0) for p in live), default=0.0)
        out["ewma_decode_s_per_tok"] = max(
            (p.get("ewma_decode_s_per_tok", 0.0) for p in live), default=0.0)
        out["prefix_hit_rate"] = (out["prefix_hit_tokens"]
                                  / max(out["prompt_tokens"], 1))
        out["per_replica"] = per
        return out

    # ---- health / failover -------------------------------------------------
    def check_health(self) -> List[int]:
        """Detect replicas whose pump died (crash or stall-death) and
        migrate their sessions to healthy peers. Runs implicitly on every
        routing decision; call it directly to force failover without
        traffic. Returns the indices newly marked failed."""
        if not self._pumped:
            return []                   # cooperative replicas cannot crash
        with self._lock:
            newly = [r for r in self._replicas
                     if not (r.removed or r.failed or r.draining)
                     and not r.server.pumping]
            for r in newly:
                self._fail_replica(r)
            return [r.idx for r in newly]

    def _fail_replica(self, r: _Replica):
        """Mark ``r`` dead and journal-replay-migrate its sessions. The
        dead pump no longer owns its engine, so the engine's host-side
        journal is readable inline post-mortem; device-side turn state is
        gone, which is exactly what the token-level replay rebuilds."""
        r.failed = True
        self._replicas_failed += 1
        for fs in [fs for fs in self._sessions.values()
                   if fs._replica is r]:
            try:
                self._migrate_session(fs, close_src=False)
            except PumpStalledError:
                pass    # no healthy peer: surfaced on the session's next turn

    def _migrate_session(self, fs: FleetSession, *, close_src: bool):
        """Re-home ``fs`` onto the least-loaded healthy peer by replaying
        its journal entry (scheduler.restore_session — the crash-recovery
        path, greedy-bit-identical). A session with no journaled turn yet
        has no state to carry; it re-pins fresh."""
        src = fs._replica
        entry = None
        if fs._sess is not None and src is not None:
            entry = src.server.engine.journal.get(fs._sess.sid)
            if close_src:
                try:
                    fs._sess.close()
                except PumpStalledError:
                    pass
        cands = [r for r in self._replicas if r.healthy and r is not src]
        if not cands:
            raise PumpStalledError(
                f"fleet session {fs.sid}: no healthy replica to migrate to")
        target = min(cands, key=self.router.load)
        if entry is not None:
            new_sid = target.server._call(
                lambda: target.server.engine.restore_session(entry))
            fs._sess = Session(target.server, new_sid)
        else:
            fs._sess = target.server.open_session()
        fs._replica = target
        target.routed += 1
        self._migrated += 1

    # ---- elastic scale -----------------------------------------------------
    def drain(self, index: int):
        """Scale-in: quiesce replica ``index`` (no new placements), finish
        its outstanding work, migrate its sessions to peers, and close it.
        Its slot in ``replicas`` stays (flagged ``removed``) so indices
        remain stable. Raises if it is the last healthy replica and it
        still owns sessions (nowhere to migrate)."""
        r = self._replicas[index]
        if r.removed:
            raise ValueError(f"replica {index} already removed")
        r.draining = True
        if not r.failed:
            r.server.run_until_idle()
        with self._lock:
            for fs in [fs for fs in self._sessions.values()
                       if fs._replica is r]:
                self._migrate_session(fs, close_src=not r.failed)
            r.server.close()
            r.removed = True
            self._replicas_drained += 1

    def add_replica(self, *, mesh=None) -> int:
        """Scale-out: bring a new replica online (sharing the fleet's
        weight arrays) and return its index. It starts cold — the router's
        least-loaded tie-break steers new placements toward it, and its
        radix digest earns affinity traffic as its cache warms."""
        srv = self._make_server(self._params, mesh)
        with self._lock:
            r = _Replica(len(self._replicas), srv, self._pumped)
            self._replicas.append(r)
            return r.idx

    # ---- routing -----------------------------------------------------------
    def _saturated(self, r: _Replica) -> bool:
        """Admission queue at the replica's OverloadPolicy bound — one more
        submit would displace or refuse. Racy read, same caveat as
        load_score."""
        ov = self._server_kw["overload"]
        if ov is None or ov.max_queue_depth is None:
            return False
        return len(r.server.engine._queue) >= ov.max_queue_depth

    def _place(self, ids, do_submit):
        """Route one placement: affinity-first candidate order, saturation
        spill, typed-overload retry across peers. ``do_submit(replica)``
        performs the replica-level action; returns (replica, its result).
        """
        self.check_health()
        with self._lock:
            cands = [r for r in self._replicas if r.healthy]
        if not cands:
            raise PumpStalledError("fleet has no healthy replicas")
        order, aff = self.router.order(cands, ids)
        last_exc = None
        for i, r in enumerate(order):
            # spill BEFORE invoking a saturated replica's shed path, as
            # long as some later candidate still has queue headroom
            if self._saturated(r) and any(not self._saturated(p)
                                          for p in order[i + 1:]):
                last_exc = last_exc or OverloadError(
                    f"replica {r.idx} admission queue full")
                continue
            try:
                res = do_submit(r)
            except OverloadError as e:          # refused: try the next peer
                last_exc = e
                continue
            except PumpStalledError as e:       # died under us: fail + retry
                with self._lock:
                    if not r.failed and not r.removed:
                        self._fail_replica(r)
                last_exc = e
                continue
            with self._lock:
                self._routed += 1
                r.routed += 1
                if r in aff:
                    self._affinity_hits += 1
                if r is not order[0]:
                    self._spilled += 1
            return r, res
        raise last_exc if last_exc is not None else OverloadError(
            "every replica refused admission")

    # ---- the LLMServer surface ---------------------------------------------
    def open_session(self) -> FleetSession:
        with self._lock:
            self._next_fsid += 1
            fs = FleetSession(self, self._next_fsid)
            self._sessions[fs.sid] = fs
        return fs

    def submit(self, prompt: str, params: Optional[SamplingParams] = None,
               *, session: Optional[int] = None,
               token_ids: Optional[List[int]] = None) -> Handle:
        """Queue one request on the best replica and return its handle
        (replica handles stream/cancel exactly like single-server ones).
        ``session=`` takes a FLEET session id — the turn goes to the
        session's pinned replica (sticky), migrating first if that replica
        died. Sessionless submits are placed fresh per request."""
        if session is not None:
            with self._lock:
                fs = self._sessions.get(session)
            if fs is None:
                raise ValueError(f"unknown fleet session id {session}")
            return self._submit_session(fs, prompt, params, token_ids)
        ids = token_ids if token_ids is not None \
            else self.tokenizer.encode(prompt)
        _, h = self._place(ids, lambda r: r.server.submit(
            prompt, params, token_ids=token_ids))
        return h

    def _submit_session(self, fs: FleetSession, prompt, params,
                        token_ids) -> Handle:
        if fs.closed:
            raise RuntimeError(f"fleet session {fs.sid} is closed")
        self.check_health()
        with self._lock:
            if fs._replica is not None and not fs._replica.healthy:
                # pinned replica died or is draining: journal-replay the
                # conversation onto a healthy peer, then continue there
                self._migrate_session(
                    fs, close_src=not (fs._replica.failed
                                       or fs._replica.removed))
        if fs._replica is None:
            # first turn: place by THIS prompt (affinity-aware), pin, and
            # submit on the pinned replica — sticky from here on
            ids = token_ids if token_ids is not None \
                else self.tokenizer.encode(prompt)

            def open_and_pin(r: _Replica):
                sess = r.server.open_session()
                return sess

            r, sess = self._place(ids, open_and_pin)
            fs._replica, fs._sess = r, sess
        # sticky turns do not spill: the retained tail lives here
        return fs._replica.server.submit(prompt, params,
                                         session=fs._sess.sid,
                                         token_ids=token_ids)

    def restore_sessions(self, journal: Union[SessionJournal, str]
                         ) -> Dict[int, FleetSession]:
        """Rebuild every journaled session across the fleet (least-loaded
        placement, one ``restore_session`` replay per entry — greedy
        continuation is bit-identical, as on a single server). Returns
        {old session id -> new FleetSession}."""
        if isinstance(journal, str):
            journal = SessionJournal.load(journal)
        out: Dict[int, FleetSession] = {}
        for entry in journal.entries():
            fs = self.open_session()
            r, sid = self._place(
                list(entry.all_tokens),
                lambda r: r.server._call(
                    lambda: r.server.engine.restore_session(entry)))
            fs._replica, fs._sess = r, Session(r.server, sid)
            out[entry.sid] = fs
        return out

    def cancel(self, handle: Handle) -> bool:
        """Cancel a handle on whichever replica owns it."""
        return handle.cancel()

    # ---- driving / lifecycle -----------------------------------------------
    def step(self) -> StepOutcome:
        """Cooperative fleets only: one engine iteration on EVERY healthy
        replica (the fleet-level analogue of ``LLMServer.step()``)."""
        if self.pumping:
            raise RuntimeError(
                "the background pumps own the step loops; wait on handles "
                "(stream()/result()) or run_until_idle() instead")
        out = StepOutcome.IDLE
        for r in self._replicas:
            if r.removed or r.failed:
                continue
            o = r.server.step()
            if o is StepOutcome.PROGRESSED:
                out = StepOutcome.PROGRESSED
            elif o is StepOutcome.WAITING and out is StepOutcome.IDLE:
                out = StepOutcome.WAITING
        return out

    def run_until_idle(self):
        """Drain every replica (queued + running work fleet-wide)."""
        if not self._pumped:
            while self.step():
                pass
            return
        while True:
            live = [r for r in self._replicas
                    if not r.removed and not r.failed]
            for r in live:
                r.server.run_until_idle()
            if all(len(r.server.engine._queue) == 0
                   and all(s.request is None for s in r.server.engine.slots)
                   for r in live):
                return

    def close(self, drain: bool = False):
        """Shut down every replica (``drain=True`` finishes outstanding
        work first). Idempotent, like the pump close it fans out to."""
        if self._closed:
            return
        self._closed = True
        for r in self._replicas:
            if not r.removed:
                r.server.close(drain=drain)

    def __enter__(self) -> "FleetServer":
        return self

    def __exit__(self, *exc_info):
        self.close()
