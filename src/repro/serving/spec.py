"""Drafter-free speculative decoding: prompt n-gram lookup drafter.

The FAME workloads (research-paper summarization, log analytics) decode
outputs that heavily *copy spans from the prompt* — tool results, fetched
paper text, log lines re-surfaced in the agent's answer — so a draft model
is overkill: the next tokens are usually sitting in the context already.
``NgramDrafter`` indexes every n-gram of the request's context (truncated
prompt + generated tokens) in a host-side hash map and proposes the
continuation of the most recent earlier occurrence of the current suffix —
the "prompt lookup decoding" idiom, O(n_max) work per committed token and
zero device work.

The proposals are verified by one batched model forward
(``models.transformer.verify`` / ``extend`` for stateful archs) and accepted
by ``sampler.accept_batched`` (greedy exact-match; rejection sampling for
temperature slots, so stochastic outputs stay distribution-correct). The
engine (serving/engine.py) owns the per-slot lifecycle, including disabling
a slot's drafter when its acceptance rate drops below
``EngineConfig.spec_min_accept``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple


class NgramDrafter:
    """Suffix n-gram -> continuation index over one request's token stream.

    ``_map`` keys are n-gram tuples (n in [n_min, n_max], n implicit in the
    tuple length); the value is the END index (exclusive) of the most recent
    occurrence that HAS a continuation token. The n-gram ending at the
    current stream tip is deliberately left unindexed until a further token
    arrives, so a lookup never matches itself.
    """

    def __init__(self, tokens: Sequence[int], *, n_min: int = 2,
                 n_max: int = 4):
        if not (1 <= n_min <= n_max):
            raise ValueError(f"bad ngram range [{n_min}, {n_max}]")
        self.n_min = n_min
        self.n_max = n_max
        self.toks: List[int] = []
        self._map: Dict[Tuple[int, ...], int] = {}
        self._done = 0              # n-gram endings <= _done are indexed
        self.extend(tokens)

    def extend(self, new_tokens: Sequence[int]):
        """Append committed tokens and index the n-grams they complete."""
        self.toks.extend(new_tokens)
        T = len(self.toks)
        # index endings e <= T-1 only: each indexed n-gram is guaranteed a
        # continuation token at self.toks[e]
        for e in range(max(self._done + 1, self.n_min), T):
            for n in range(self.n_min, min(self.n_max, e) + 1):
                self._map[tuple(self.toks[e - n:e])] = e
        self._done = max(self._done, T - 1)

    def draft(self, max_len: int) -> List[int]:
        """Up to ``max_len`` proposed continuation tokens (may be empty).

        Longest-suffix match first: an (n_max)-gram hit is a stronger signal
        than a shorter one, so n walks down from n_max to n_min. The most
        recent occurrence can sit near the stream tip with little lookahead
        left (a period-1 loop matches one token back), so the draft
        SELF-EXTENDS: the proposed tokens are appended to a hypothetical
        suffix and looked up again until ``max_len`` is reached or the chain
        breaks.
        """
        if max_len <= 0:
            # a clamped draft budget (tight remaining/capacity window) must
            # not index with an empty suffix below
            return []
        out: List[int] = []
        tail = list(self.toks[-self.n_max:])
        while len(out) < max_len:
            e = None
            for n in range(min(self.n_max, len(tail)), self.n_min - 1, -1):
                e = self._map.get(tuple(tail[-n:]))
                if e is not None:
                    break
            if e is None:
                break
            span = self.toks[e:e + max_len - len(out)]
            if not span:
                break
            out.extend(span)
            tail = (tail + span)[-self.n_max:]
        return out
