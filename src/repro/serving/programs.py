"""Jit-program layer of the serving engine.

This module is the device side of the serving stack: every jit-compiled
computation the scheduler (serving/scheduler.py) dispatches lives here, with
no request/queue/session bookkeeping mixed in —

* bucketed **prefill** (one compile per prompt-length bucket, in-jit per-slot
  cache splice),
* **extend** continuations (dense chunked prefill and paged suffix prefill
  through block tables),
* the chunked **decode** loop (``lax.while_loop``, per-slot done mask,
  on-device per-slot sampling with per-request key chains),
* the fused speculative **verify** step (forward + accept + accept-length
  state rewind in ONE jit),
* snapshot-arena **capture/restore** splices (per-prefix recurrent-state
  sharing).

The scheduler owns the mutable state (cache, params, slots, counters) and
passes it through; ``EnginePrograms`` owns the model and the compiled
callables. Splitting the layers keeps the step loop readable and lets the
program set be reused by any frontend (``repro.serving.server.LLMServer``,
the deprecated ``ServingEngine`` shim, future batch runners) without
re-tracing.
"""
from __future__ import annotations

import contextlib
import random
import time
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import use_rules
from repro.serving.faults import DeadLetterError, RetryPolicy, TransientFault
from repro.serving.sampler import accept_batched, sample_batched


def slot_extract(cache, slot):
    """Single-row view of slot ``slot``: scan leaves are [L, B, ...], tail
    leaves [B, ...] (mirrors ``slot_splice``)."""
    def _scan_get(full):
        return jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=1)

    def _tail_get(full):
        return jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=0)

    return {k: jax.tree.map(_scan_get if k == "scan" else _tail_get, cache[k])
            for k in cache}


def slot_splice(cache, cache1, slot):
    """Write a single-row cache pytree back into row ``slot``."""
    def _scan_leaf(full, one):
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype),
            (jnp.int32(0), slot) + (jnp.int32(0),) * (full.ndim - 2))

    def _tail_leaf(full, one):
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype),
            (slot,) + (jnp.int32(0),) * (full.ndim - 1))

    return {k: jax.tree.map(_scan_leaf if k == "scan" else _tail_leaf,
                            cache[k], cache1[k])
            for k in cache}


def select_rows(new_cache, old_cache, keep):
    """Per-row cache select: rows with ``keep`` take the new cache, the rest
    keep the old one bit-exactly. Scan leaves are [L, B, ...], tail leaves
    [B, ...] (the ``slot_extract`` convention)."""
    def _scan_sel(n, o):
        return jnp.where(keep.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o)

    def _tail_sel(n, o):
        return jnp.where(keep.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)

    return {k: jax.tree.map(_scan_sel if k == "scan" else _tail_sel,
                            new_cache[k], old_cache[k])
            for k in new_cache}


def auto_buckets(capacity: int, lo: int = 32) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to (and including) capacity."""
    buckets = []
    b = min(lo, capacity)
    while b < capacity:
        buckets.append(b)
        b *= 2
    buckets.append(capacity)
    return tuple(buckets)


class EnginePrograms:
    """The compiled program set for one (model config, engine config) pair.

    Stateless apart from the model/params-independent compile caches: the
    scheduler threads cache/params in and out of every call. ``keys``/
    ``counts`` in the decode loop implement per-request RNG chains — row
    ``b`` samples its ``t``-th token with ``fold_in(keys[b], counts[b])``,
    so a request's stochastic output is a function of its own seed and
    position only, never of batch composition (see SamplingParams.seed).
    """

    def __init__(self, model, cfg, engine_cfg, *, capacity: int,
                 num_slots: int, eos_id: int, freeze_done_rows: bool,
                 snapshots: bool, spec: bool, donate: bool,
                 injector=None, retry: RetryPolicy = None,
                 watchdog_s: float = None, rules=None):
        self.model = model
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.capacity = capacity
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.freeze_done_rows = freeze_done_rows
        # sharding rule set (distributed/sharding.py "serve" phase) or None.
        # Dispatches run under use_rules(rules), so the model's constrain()
        # calls resolve to NamedShardings at trace time and the jits
        # partition over the mesh; None (single device) traces no
        # constraints at all — the pre-mesh programs, byte-for-byte.
        self.rules = rules
        # fault layer: every public dispatch goes through _run (injector
        # hook + bounded retry of TransientFaults + watchdog accounting)
        self.injector = injector
        self.retry = retry or RetryPolicy()
        self.watchdog_s = watchdog_s
        self.dispatch_retries = 0       # TransientFaults retried
        self.watchdog_stalls = 0        # dispatches slower than watchdog_s
        self._retry_rng = random.Random(0)   # backoff jitter (deterministic)

        dargs = (1,) if donate else ()
        self._prefill_jit = jax.jit(self._prefill_fn, donate_argnums=dargs)
        self._decode_chunk_jit = jax.jit(self._decode_chunk_fn,
                                         donate_argnums=dargs)
        self._extend_jit = jax.jit(self._extend_fn, donate_argnums=dargs,
                                   static_argnames=("sample",))
        self._extend_paged_jit = jax.jit(self._extend_paged_fn,
                                         donate_argnums=dargs,
                                         static_argnames=("sample",))
        if snapshots:
            d0 = (0,) if donate else ()
            self._snap_capture_jit = jax.jit(self._snap_capture_fn,
                                             donate_argnums=d0)
            self._snap_restore_jit = jax.jit(self._snap_restore_fn,
                                             donate_argnums=d0)
        if spec:
            # ONE jit per verify step for every arch: forward + accept +
            # accept-length state rewind (model.verify_commit) fused
            self._verify_jit = jax.jit(self._verify_fn, donate_argnums=dargs)

    # ---- guarded dispatch --------------------------------------------------
    def _run(self, site: str, fn, *args, **kwargs):
        """One guarded device dispatch: the fault-injector hook fires first
        (it may stall — counted against the watchdog — or raise), then the
        jit call. ``TransientFault``s retry with exponential backoff +
        jitter up to ``retry.max_attempts``, then dead-letter; anything else
        propagates untouched for the scheduler's isolation paths (a real jit
        exception is never retried — with donation on, the inputs may
        already be consumed)."""
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    self.injector.check(site)
                # context managers are single-use: build one per attempt
                with (use_rules(self.rules) if self.rules is not None
                      else contextlib.nullcontext()):
                    out = fn(*args, **kwargs)
            except TransientFault as e:
                attempt += 1
                self.dispatch_retries += 1
                if attempt >= self.retry.max_attempts:
                    raise DeadLetterError(
                        f"{site}: {self.retry.max_attempts} attempts "
                        "exhausted") from e
                time.sleep(self.retry.delay(attempt, self._retry_rng))
                continue
            if (self.watchdog_s is not None
                    and time.perf_counter() - t0 > self.watchdog_s):
                self.watchdog_stalls += 1
            return out

    def prefill(self, *args):
        return self._run("prefill", self._prefill_jit, *args)

    def extend(self, *args, sample: bool):
        return self._run("extend", self._extend_jit, *args, sample=sample)

    def extend_paged(self, *args, sample: bool):
        return self._run("extend_paged", self._extend_paged_jit, *args,
                         sample=sample)

    def decode_chunk(self, *args):
        return self._run("decode", self._decode_chunk_jit, *args)

    def verify(self, *args):
        return self._run("verify", self._verify_jit, *args)

    def snap_capture(self, *args):
        return self._run("snap_capture", self._snap_capture_jit, *args)

    def snap_restore(self, *args):
        return self._run("snap_restore", self._snap_restore_jit, *args)

    # ---- prefill / extend --------------------------------------------------
    def _prefill_fn(self, params, cache, tokens, positions, slot, length, key,
                    temperature, top_k):
        """Prefill one (padded) prompt and splice it into the shared cache.

        Everything — forward pass, per-slot cache splice, first-token sample —
        happens in one jit, compiled once per bucket length.
        """
        cache1 = self.model.init_cache(1, self.capacity)
        batch = {("frames" if self.cfg.modality == "audio_frames" else "tokens"): tokens,
                 "positions": positions}
        logits, cache1 = self.model.prefill(params, batch, cache1,
                                            length=length, with_logits="last")
        tok = self._sample_last(logits, length, key, temperature, top_k)
        # splice the single-row cache into slot `slot` of the shared cache;
        # scan caches are [L, B, ...] (batch dim 1), tail caches [B, ...]
        return slot_splice(cache, cache1, slot), tok

    def _sample_last(self, logits, length, key, temperature, top_k):
        """Sample one token from the logits at position ``length - 1``
        (or from already-sliced ``with_logits="last"`` logits [B, 1, V])."""
        if logits.shape[1] == 1:
            last = logits[:, 0]                                      # [1, V]
        else:
            last = jax.lax.dynamic_index_in_dim(logits, length - 1, axis=1,
                                                keepdims=False)      # [1, V]
        tok = sample_batched(last, key, temperature=temperature[None],
                             top_k=top_k[None], vocab_limit=self.cfg.vocab_size)
        return tok[0]

    def _extend_fn(self, params, cache, tokens, positions, slot, start,
                   length, key, temperature, top_k, *, sample: bool):
        """Dense chunked-prefill continuation for one slot.

        Extract the slot's cache row, run ``model.extend`` (the chunk attends
        to the already-prefilled prefix + itself; recurrent state resumes),
        splice the row back — all in one jit, compiled once per chunk shape.
        ``sample=True`` (the prompt's final chunk) additionally unembeds and
        samples at the last valid position; intermediate chunks skip the
        unembed matmul entirely.
        """
        cache1 = slot_extract(cache, slot)
        tok_key = ("frames" if self.cfg.modality == "audio_frames" else "tokens")
        batch = {tok_key: tokens, "positions": positions}
        logits, cache1 = self.model.extend(
            params, batch, cache1, start, length=length,
            with_logits="last" if sample else False)
        tok = (self._sample_last(logits, length, key, temperature, top_k)
               if sample else jnp.int32(-1))
        return slot_splice(cache, cache1, slot), tok

    def _extend_paged_fn(self, params, pool, tokens, positions, bt, start,
                         length, key, temperature, top_k, *, sample: bool):
        """Paged prefill: write the chunk's K/V into this request's pages and
        attend to the full block-table view (shared prefix pages included —
        the radix-matched prefix is never recomputed)."""
        tok_key = ("frames" if self.cfg.modality == "audio_frames" else "tokens")
        batch = {tok_key: tokens, "positions": positions}
        logits, pool = self.model.extend(
            params, batch, pool, start, length=length, block_tables=bt,
            with_logits="last" if sample else False)
        tok = (self._sample_last(logits, length, key, temperature, top_k)
               if sample else jnp.int32(-1))
        return pool, tok

    # ---- chunked decode ----------------------------------------------------
    def _decode_chunk_fn(self, params, cache, last_tok, cache_lens, remaining,
                         done, temps, top_ks, keys, prompt_lens,
                         block_tables=None):
        """Decode up to ``decode_chunk`` tokens for every live slot on device.

        Per-slot done mask (EOS / budget / capacity); finished or empty slots
        keep running in the fixed batch but stop emitting and stop advancing
        their cache row. ``keys`` [B, 2] are per-request PRNG keys and
        ``prompt_lens`` [B] each row's prompt length — the number of tokens
        row ``b`` has sampled so far is then ``cache_lens[b] -
        prompt_lens[b] + 1`` (derived in-jit, no extra host transfer or
        loop carry), and its next token uses ``fold_in(keys[b], count)``:
        stochastic outputs are reproducible per request regardless of which
        other requests share the batch. Statically greedy batches
        (``temps is None``) trace no RNG at all. Returns everything the host
        needs in one pull.
        """
        chunk = self.engine_cfg.decode_chunk
        B = self.num_slots
        eos = self.eos_id
        tok_buf = jnp.zeros((chunk, B), jnp.int32)
        emit_buf = jnp.zeros((chunk, B), bool)

        def cond(st):
            i = st[0]
            return (i < chunk) & jnp.any(~st[5])

        def body(st):
            i, cache, last, clens, rem, done, tb, eb = st
            if self.cfg.modality == "audio_frames":
                # same frame-embedding stub the admission path applies
                toks = jax.nn.one_hot(last[:, None] % self.cfg.d_model,
                                      self.cfg.d_model,
                                      dtype=jnp.dtype(self.cfg.dtype))
                batch = {"frames": toks, "positions": clens[:, None]}
            else:
                batch = {"tokens": last[:, None], "positions": clens[:, None]}
            logits, new_cache = self.model.decode_step(params, batch, cache,
                                                       clens,
                                                       block_tables=block_tables)
            if self.freeze_done_rows:
                # stateful archs: a done-masked row must not keep advancing
                # its recurrent / conv / mLSTM / sLSTM state on a stale
                # input — above all a spec-handled slot sitting this chunk
                # out, which continues decoding next step. Full-attention
                # rows skip this (their stale write is position-masked and
                # idempotent; their caches are also the big ones).
                cache = select_rows(new_cache, cache, ~done)
            else:
                cache = new_cache
            if temps is None:                   # statically greedy batch:
                row_keys = None                 # no RNG / sort in the loop
            else:
                cnts = clens - prompt_lens + 1  # tokens sampled so far
                row_keys = jax.vmap(jax.random.fold_in)(keys, cnts)
            nxt = sample_batched(logits[:, 0], row_keys, temperature=temps,
                                 top_k=top_ks, vocab_limit=self.cfg.vocab_size)
            emit = ~done
            last = jnp.where(emit, nxt, last)
            clens = clens + emit.astype(jnp.int32)
            rem = rem - emit.astype(jnp.int32)
            done = done | (emit & ((rem <= 0) | (nxt == eos)
                                   | (clens >= self.capacity - 1)))
            tb = tb.at[i].set(jnp.where(emit, nxt, 0))
            eb = eb.at[i].set(emit)
            return (i + 1, cache, last, clens, rem, done, tb, eb)

        st = (jnp.int32(0), cache, last_tok, cache_lens, remaining, done,
              tok_buf, emit_buf)
        _, cache, last_tok, cache_lens, remaining, done, tok_buf, emit_buf = \
            jax.lax.while_loop(cond, body, st)
        return cache, tok_buf, emit_buf, cache_lens, remaining, done

    # ---- speculative decode: jit'd verify + accept + rewind ----------------
    def _verify_fn(self, params, cache, tokens, clens, lens, temps, top_ks,
                   key, block_tables=None):
        """One batched speculative verify step for every slot — any arch.

        tokens [B, S]: ``[last, d_1 .. d_k, pad]`` per row (S = spec_len+1),
        lens [B] = k+1 valid inputs (0 for rows sitting this verify out —
        empty, done, or undrafted slots: no writes, no commits; undrafted
        slots take the chunked decode loop this step instead). One forward
        scores all draft positions (staging per-position states for stateful
        blocks); accept_batched picks the matched prefix + a correction/
        bonus token per drafted row; ``model.verify_commit`` then rewinds
        every stateful block to its row's accepted length with gathers /
        ring splices — all inside this one jit, no per-slot replay.
        """
        positions = clens[:, None] + jnp.arange(tokens.shape[1],
                                                dtype=jnp.int32)[None, :]
        batch = {"tokens": tokens, "positions": positions}
        logits, staged = self.model.verify(params, batch, cache, clens,
                                           lens=lens,
                                           block_tables=block_tables)
        out_tok, out_len = accept_batched(
            logits, tokens, jnp.maximum(lens - 1, 0), key,
            temperature=temps, top_k=top_ks,
            vocab_limit=self.cfg.vocab_size, use_kernel=self.cfg.use_pallas)
        cache = self.model.verify_commit(staged, clens, out_len, lens)
        return cache, out_tok, out_len

    # ---- per-prefix snapshot splices (snapshot mode) -----------------------
    def _snap_capture_fn(self, arena, cache, sid, slot):
        """Copy slot ``slot``'s complete state row into arena row ``sid``."""
        return slot_splice(arena, slot_extract(cache, slot), sid)

    def _snap_restore_fn(self, cache, arena, sid, slot):
        """Restore arena row ``sid`` into slot ``slot`` — equivalent to
        having prefilled the snapshot's prefix into that slot."""
        return slot_splice(cache, slot_extract(arena, sid), slot)
