"""Paged KV-cache pool: fixed-size pages, block tables, refcounted sharing.

Instead of one dense cache row per slot (PR-1 layout, ``[num_slots, capacity,
K, hd]`` per layer), paged mode keeps ONE device-resident pool of
``num_pages`` fixed-size pages per layer (``[num_pages, page_size, K, hd]``)
and gives every request a *block table* — the ordered list of pages holding
its sequence. Two requests whose prompts share a prefix point their leading
block-table entries at the *same* pages (found via serving/radix.py), so the
shared prefix is prefilled once and stored once: prefill work and cache
memory scale with *unique* tokens, not total tokens — the property that makes
N agents × one system prompt sublinear (PAPER.md §3.3, AgentX).

The device tensors reuse the model's cache pytree structure
(``transformer.cache_spec`` with batch=num_pages, capacity=page_size), so the
scan-over-layers stack and the engine's donation/jit plumbing are unchanged;
only attention reads/writes route through block tables
(``models/attention.py`` paged helpers, ``kernels/paged_decode_attention``).

Page 0 is reserved as a trash page: block-table padding for unused entries
and empty slots points at it, so scatter writes from masked-out lanes land
somewhere harmless.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

TRASH_PAGE = 0


class PagePool:
    """Host-side page allocator over the device pool's first axis.

    All-or-nothing allocation; freeing is idempotent-unsafe by design (a page
    must be owned by exactly one of: free list, radix tree, a live request).
    """

    def __init__(self, num_pages: int, *, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages={num_pages} <= reserved={reserved}")
        self.num_pages = num_pages
        self.reserved = reserved
        # LIFO free list, low pages first out (stable for tests); the
        # companion set makes the double-free check O(1)
        self._free: List[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._free_set = set(self._free)
        self.peak_in_use = 0
        self.injector = None        # chaos hook (serving/faults.FaultInjector)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.num_pages - self.reserved - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages or None (never a partial allocation)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > 0 and self.injector is not None and self.injector.take("pool.alloc"):
            return None             # injected exhaustion: the caller's normal
                                    # evict-then-retry / backoff path handles it
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(pages)
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return pages

    def free(self, pages: List[int]):
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate pages in free: {pages}")
        for p in pages:
            if not (self.reserved <= p < self.num_pages):
                raise ValueError(f"free of invalid page {p}")
            if p in self._free_set:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)
        self._free_set.update(pages)


def paged_cache_spec(cfg, num_pages: int, page_size: int):
    """ShapeDtypeStructs of the paged pool: the model's cache pytree with the
    batch axis re-purposed as the page axis and capacity as the page size."""
    from repro.models import transformer as tfm
    return tfm.cache_spec(cfg, num_pages, page_size)


def init_paged_cache(cfg, num_pages: int, page_size: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_cache_spec(cfg, num_pages, page_size))


def shard_rows(pool, cfg, rules, mesh):
    """Lay the page pool / snapshot arena out across a serving mesh.

    Pages stay the allocation unit — the host-side ``PagePool`` /
    ``SnapshotArena`` bookkeeping is untouched — but the device tensors get
    NamedShardings from the serve rules: the page / snapshot-row batch axis
    shards over ``("data",)`` and the KV-head / recurrent-channel dims over
    ``("model",)`` (both batch-like, so values are bit-exact; see
    distributed/sharding.py). Dims that don't divide their mesh axis fall
    back to replicated per leaf. Works for both pool flavors because they
    reuse the model cache pytree structure.
    """
    from repro.distributed import sharding
    return sharding.shard_put(pool, sharding.cache_pspecs(cfg, rules), mesh)


def supports_paged(cfg) -> tuple:
    """(ok, reason): paged mode needs every layer to be full (non-windowed)
    attention — KV of a position then depends only on the token prefix, so
    pages are shareable across requests. Recurrent / conv / xLSTM state and
    windowed attention share prefixes through per-prefix state snapshots
    instead (``supports_snapshots``)."""
    from repro.configs import base as cfgbase
    bad = [k for k in cfg.layer_kinds if k not in (cfgbase.ATTN, cfgbase.ATTN_MOE)]
    if bad:
        return False, f"non-attention layers {sorted(set(bad))} keep per-slot state"
    if cfg.sliding_window is not None:
        return False, "sliding-window attention: ring cache is not page-shareable"
    return True, ""


def supports_snapshots(cfg) -> tuple:
    """(ok, reason): per-prefix recurrent-state snapshots need the whole
    decode state to be O(1)/window-bounded per sequence (recurrent / conv /
    mLSTM / sLSTM state, ring KV) — then the state after any prefix boundary
    is a fixed-size pytree that one arena slot can hold, and restoring it is
    equivalent to re-prefilling the whole prefix. A full-attention layer's
    KV grows with the prefix, so those archs share via KV pages instead
    (``supports_paged``)."""
    if cfg.is_subquadratic:
        return True, ""
    return False, ("full-attention KV grows with the prefix; use paged KV "
                   "sharing instead")


class SnapshotArena:
    """Host-side slot allocator over the snapshot arena's batch axis.

    The device arena is the model's cache pytree with the batch axis
    re-purposed as snapshot slots (one row = the complete per-sequence state
    at one radix-node boundary: recurrent h, conv window, mLSTM (C, n, m),
    sLSTM state, ring-KV cache — the ring write cursor is implicit in the
    boundary length, position p living at slot ``p % window``). Slots are
    owned by exactly one of: this free list, the radix tree (one node per
    boundary), or transiently the engine between capture and trie insert —
    mirroring the PagePool ownership rule, with the radix refcounts pinning
    a snapshot's node exactly like a page's.
    """

    def __init__(self, num_snaps: int):
        if num_snaps < 1:
            raise ValueError(f"num_snaps must be >= 1, got {num_snaps}")
        self.num_snaps = num_snaps
        self._free: List[int] = list(range(num_snaps - 1, -1, -1))
        self._free_set = set(self._free)
        self.peak_in_use = 0
        self.injector = None        # chaos hook (serving/faults.FaultInjector)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.num_snaps - len(self._free)

    def alloc(self) -> Optional[int]:
        """One slot id, or None when the arena is full (the caller evicts
        from the radix tree and retries, or skips the capture)."""
        if self.injector is not None and self.injector.take("snap.alloc"):
            return None             # injected exhaustion: capture is skipped
        if not self._free:
            return None
        sid = self._free.pop()
        self._free_set.discard(sid)
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return sid

    def free(self, snaps: List[int]):
        if len(set(snaps)) != len(snaps):
            raise ValueError(f"duplicate snaps in free: {snaps}")
        for s in snaps:
            if not (0 <= s < self.num_snaps):
                raise ValueError(f"free of invalid snap {s}")
            if s in self._free_set:
                raise ValueError(f"double free of snap {s}")
        self._free.extend(snaps)
        self._free_set.update(snaps)


def block_table_array(rows: List[List[int]], width: int):
    """Pad per-slot page lists to a rectangular [B, width] int32 device array
    (unused entries point at the trash page)."""
    padded = [list(r[:width]) + [TRASH_PAGE] * (width - len(r)) for r in rows]
    return jnp.asarray(padded, jnp.int32)
