"""Scheduler layer of the serving engine: continuous batching over the
jit-program set in serving/programs.py.

The scheduler owns everything host-side: the request queue (FIFO within
priority classes), slot lifecycle (admit → decode/verify → finalize), the
paged KV pool / radix trie / snapshot arena bookkeeping, speculative-decode
drafting, stop sequences, cancellation, per-request RNG chains, **sessions**
(multi-turn conversations whose end-of-generation state is kept for the next
turn), and every ``stats()`` counter. All device work is dispatched through
``EnginePrograms``; see programs.py for the fast-path structure (bucketed
prefill, chunked decode, paged/radix sharing, snapshots, spec verify) and
docs/serving.md for the full knob + counter reference.

Public frontends:

* ``repro.serving.server.LLMServer`` — the session-oriented API (streaming
  handles, cancellation, multi-turn reuse). New code starts there.
* ``repro.serving.engine.ServingEngine`` — the deprecated PR-1 façade
  (``submit(prompt, **kwargs)`` / ``generate``), a thin shim over
  ``enqueue``.

Sessions and multi-turn reuse
-----------------------------

``open_session()`` returns a session id; every ``enqueue(..., session=sid)``
is one *turn*. At end of turn the engine keeps the conversation's tail state
at its exact (non-block-aligned) end-of-generation boundary, per arch
family:

* **paged** (full-attention archs): the turn's complete KV pages are adopted
  into the radix trie as usual, and the *partial tail page* — the page
  holding the positions past the last block boundary, including the
  generated tokens — stays owned by the session. The next turn's block table
  is ``radix-matched pages + tail page + fresh pages`` and prefill starts at
  the exact token the conversation left off, not at the last page boundary.
* **snapshots** (stateful archs): the slot's complete state is captured into
  a session-owned arena row at the exact end-of-generation length (trie
  snapshots only exist at block boundaries). The next turn restores it and
  prefills only the new message.

Turn N+1 must extend turn N's conversation: the session tracks the
conversation *text* (submitted prompt + generated output) and, when the new
prompt extends it, builds the token stream as ``previous tokens +
encode(delta)`` — exact token-level continuation, immune to tokenizer
round-trip drift. A prompt that rewrites history just resets the tail and
falls back to plain radix sharing. Greedy outputs are bit-identical with and
without session reuse (tests/test_server_api.py).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import math
import random

import jax
import jax.numpy as jnp

from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serving import kvpool
from repro.serving.faults import (DeadLetterError, DeadlineExceeded,
                                  OverloadError, RequestFault, RetryPolicy,
                                  ShedError)
from repro.serving.journal import JournalEntry, SessionJournal
from repro.serving.programs import EnginePrograms, auto_buckets
from repro.serving.radix import RadixTree
from repro.serving.spec import NgramDrafter
from repro.serving.tokenizer import ByteTokenizer


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters (the ``submit()`` kwargs of the
    deprecated API, plus stop / seed / priority).

    max_new_tokens: output token budget (must leave a >= 1 token prompt
                    window: max_new_tokens <= capacity - 2).
    temperature:    0.0 = greedy; > 0 samples on device per slot.
    top_k:          0 = no filter; >= vocab also degenerates to no filter.
    stop:           stop strings, checked host-side at every chunk sync on
                    the decoded text; generation halts at the first token
                    whose decoded prefix contains a stop and tokens after it
                    are trimmed from the result (a stop split across a chunk
                    boundary is still caught — the check sees the full text).
    seed:           per-request RNG seed. Stochastic sampling draws token t
                    from fold_in(PRNGKey(seed), t), so the same seed gives
                    the same output regardless of batch composition or
                    num_slots. None derives a per-request key from the
                    engine seed and request id (still composition-
                    independent, just not caller-chosen). Speculative
                    temperature slots remain distribution-correct but draw
                    from the shared verify key — pin outputs with spec off.
    priority:       admission class; higher admits first, FIFO within a
                    class (radix-aware admission grouping may still pull a
                    prefix-sharing request forward within one engine step).
    deadline_s:     wall-clock budget from submit; checked at every chunk
                    sync, so an expired request terminates TIMED_OUT within
                    one decode chunk of the deadline with all resources
                    freed (partial output kept, like cancel). None falls
                    back to the server-level ``default_deadline_s``.
    """
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: int = 0
    stop: Tuple[str, ...] = ()
    seed: Optional[int] = None
    priority: int = 0
    deadline_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving fast-path knobs.

    prefill_buckets: explicit bucket lengths; None → auto powers-of-two;
                     empty tuple → exact-length prefill (one compile per
                     distinct prompt length — the pre-fast-path behaviour,
                     kept for A/B benchmarking).
    decode_chunk:    decode tokens per jit'd inner loop (1 → one host sync
                     per token, the pre-fast-path behaviour). All-greedy
                     batches additionally compile a sampler-free loop body
                     (no per-step RNG / top-k sort).
    block_w:         decode-attention KV block; cache capacity is rounded up
                     to a multiple of it so the kernel never re-pads.
    donate:          donate the shared cache to prefill/decode jits
                     (None → auto: on everywhere except CPU, where XLA
                     ignores donation and warns).
    cache_mode:      "dense" (PR-1 per-slot cache rows) or "paged" (radix
                     prefix sharing). On full-attention archs "paged" means
                     one KV page pool + per-request block tables
                     (kvpool.supports_paged); on stateful archs (recurrent /
                     conv / xLSTM / ring-KV — kvpool.supports_snapshots) it
                     keeps dense rows and shares prefixes through per-prefix
                     recurrent-state snapshots instead.
    page_size:       KV tokens per page in paged mode; capacity is rounded up
                     to a multiple of it. Smaller pages share finer prefixes
                     at more gather overhead. Snapshot mode reuses it as the
                     radix block granularity.
    num_pages:       device pages in the pool (None → auto: trash page +
                     2 × num_slots × pages-per-request, leaving headroom for
                     retained prefixes before LRU eviction kicks in).
    num_snapshots:   snapshot-arena rows in snapshot mode (None → auto:
                     ~num_slots × boundaries-per-request + headroom). Each
                     row holds one complete per-sequence state, so memory is
                     num_snapshots × state-size — size it to taste and let
                     LRU eviction manage the rest.
    snap_stride:     radix blocks between snapshot boundaries (1 = capture at
                     every block, the finest prefix reuse; larger strides
                     trade hit depth for fewer arena rows and fewer prefill
                     chunk splits).
    spec_len:        max draft tokens per speculative verify step (0 = off).
                     A per-slot n-gram lookup drafter (serving/spec.py, no
                     draft model) proposes continuations; one verify forward
                     scores every draft position at once and an accept/
                     rollback step commits the matched prefix. Greedy slots
                     accept by exact match (outputs bit-identical to
                     non-speculative decode); temperature slots use
                     rejection-sampling acceptance (distribution-correct).
    spec_ngram_min/max: suffix n-gram lengths the drafter indexes.
    spec_min_accept: per-slot drafting turns off for the rest of a request
                     once its acceptance rate drops below this (after
                     spec_warmup drafted tokens) — unpredictable outputs
                     then pay zero verify overhead.
    spec_warmup:     drafted tokens per slot before adaptive disable engages.
    mesh:            JAX device mesh with ("data", "model") axes to shard the
                     serving programs over (``launch.mesh.make_test_mesh`` /
                     ``make_production_mesh``). None → ``make_host_mesh()``,
                     a degenerate 1×1 mesh: every existing single-device
                     path is byte-for-byte unchanged. With > 1 device the
                     scheduler lays params, the per-slot cache, the paged
                     page pool and the snapshot arena out with the bit-exact
                     "serve" rules (distributed/sharding.py): slot/page/row
                     batch axes over "data", heads / KV heads / experts /
                     mlp-up / vocab / rnn channels over "model". Greedy
                     outputs are bit-identical to single-device in every
                     cache mode (tests/test_mesh_serving.py).
    """
    prefill_buckets: Optional[Tuple[int, ...]] = None
    decode_chunk: int = 16
    block_w: int = 256
    donate: Optional[bool] = None
    cache_mode: str = "dense"
    page_size: int = 16
    num_pages: Optional[int] = None
    num_snapshots: Optional[int] = None
    snap_stride: int = 1
    spec_len: int = 0
    spec_ngram_min: int = 2
    spec_ngram_max: int = 4
    spec_min_accept: float = 0.35
    spec_warmup: int = 64
    mesh: Optional[object] = None     # jax.sharding.Mesh (kept untyped so a
                                      # config never forces jax device init)


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Bounded-admission / load-shedding / preemption knobs (all off by
    default field-wise; pass an instance to enable overload control).

    max_queue_depth: global queued-request cap. A submit over the cap
                     displaces the *youngest strictly-lower-priority* queued
                     request (shed with ``ShedError``) or, when none exists,
                     raises ``OverloadError`` to the submitter.
    class_depth:     per-priority queued-request caps ({priority: depth}).
                     A full class rejects its own submits with
                     ``OverloadError`` — one tenant class cannot displace
                     its own peers by hammering the queue.
    max_queue_age_s / class_age_s:
                     queued requests older than the cap (per-priority value
                     wins over the global one) are shed at the next step —
                     a request that has already waited past usefulness
                     terminates typed instead of aging into a timeout.
    shed_on_deadline: predictive shedding — a queued request whose remaining
                     deadline cannot cover its predicted service time (EWMA
                     of observed per-token prefill/decode rates) is shed
                     *immediately* rather than admitted to certainly time
                     out. No-op until the engine has observed one completion.
    shed_margin:     safety factor on the prediction (1.0 = shed when
                     remaining < predicted; larger sheds earlier).
    preempt:         under admission pressure, a running strictly-lower-
                     priority decode is preempted at the chunk boundary and
                     re-queued for bit-identical resumption (RNG chain and
                     token stream continue exactly; see ``_preempt_slot``).
    breaker_threshold: consecutive dispatch dead-letters that trip the
                     circuit breaker (0 disables it).
    breaker_cooldown_s: submits are rejected with ``OverloadError`` for this
                     long after the breaker trips; any successful dispatch
                     resets the consecutive-failure count.
    """
    max_queue_depth: Optional[int] = None
    class_depth: Optional[Dict[int, int]] = None
    max_queue_age_s: Optional[float] = None
    class_age_s: Optional[Dict[int, float]] = None
    shed_on_deadline: bool = True
    shed_margin: float = 1.0
    preempt: bool = True
    breaker_threshold: int = 5
    breaker_cooldown_s: float = 1.0


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: int = 0
    stop: Tuple[str, ...] = ()
    priority: int = 0
    # filled by the engine
    prompt_tokens: int = 0
    truncated_tokens: int = 0      # dropped at the hard capacity window
    prefix_hit_tokens: int = 0     # prompt tokens served from shared pages /
                                   # restored snapshots / session tail state
    output_text: str = ""
    output_ids: Optional[List[int]] = None   # generated token ids (trimmed)
    output_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    latency_s: float = 0.0
    admit_index: int = -1
    first_token_s: float = 0.0     # TTFT: submit -> first sampled token
                                   # (set at first activation; preserved
                                   # across preempt/resume)
    preempted: int = 0             # times this request was preempted
    finished: bool = False         # reached a terminal status
    cancelled: bool = False
    status: str = "queued"         # RequestStatus value (serving/faults.py):
                                   # queued/running -> completed | cancelled
                                   # | timed_out | failed | shed
    error: Optional[BaseException] = None    # why FAILED / TIMED_OUT
    deadline_s: Optional[float] = None       # resolved (param or server default)
    _submit_t: float = 0.0
    _retry_at: float = 0.0         # admission backoff: skip until this time
    _admit_attempts: int = 0       # failed admission tries (pool exhaustion)
    _ids: Optional[list] = None    # tokenized prompt, cached across admission
                                   # retries (paged head-of-line waits) and
                                   # pre-built by session turn continuation
    _grouped: bool = False         # moved up the queue by radix-aware
                                   # admission batching (paged mode)
    _key: Optional[object] = None  # per-request PRNG key (chain base)
    _key0: Optional[object] = None # fold_in(_key, 0): first-token sample key
                                   # (re-derived as fold_in(_key, k) when a
                                   # preempted request resumes k tokens in)
    _sess: Optional[object] = None # owning _SessionState for session turns
    _pre_gen: Optional[list] = None  # preemption: tokens generated before
                                     # the preempt; re-prefilled on resume
    _orig_plen: int = 0            # admitted prompt length (pre tokens
                                   # excluded) — fixed at first activation


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    cache_len: int = 0
    prompt_len: int = 0
    remaining: int = 0
    generated: Optional[list] = None
    stopped: bool = False                 # device state ran past the kept
                                          # tokens (stop-sequence trim, or a
                                          # spec accept truncated at EOS) —
                                          # tail snapshot capture must skip
    # paged mode bookkeeping
    token_ids: Optional[list] = None      # prompt ids (post-truncation)
    pages_shared: Optional[list] = None   # radix-matched prefix pages (tree-owned)
    pages_priv: Optional[list] = None     # this request's own pages
    node: Optional[object] = None         # pinned radix node
    sess_tail_page: int = -1              # page consumed from the session
                                          # tail (returned to it on cancel)
    # speculative decoding bookkeeping
    drafter: Optional[NgramDrafter] = None
    spec_on: bool = False                 # adaptive per-slot enable
    spec_drafted: int = 0                 # draft tokens proposed for this slot
    spec_accepted: int = 0                # ... of which verify accepted


@dataclasses.dataclass
class _SessionState:
    """One conversation's retained state between turns.

    ``all_tokens`` is the exact token stream of the conversation so far
    (prompt + generated, stop-trimmed); its first ``len - 1`` tokens are
    *processed* (KV / recurrent state exists for them), the final token is
    the sampled-but-unconsumed continuation. ``text`` is the matching
    conversation text — the next turn's prompt must extend it for the tail
    to be reused. Tail resources are owned by the session (never by the
    radix tree or the free lists): ``tail_page`` in paged mode, ``tail_snap``
    in snapshot mode, plus a pin (``node``) on the trie path covering the
    conversation's complete blocks so LRU eviction can't open a gap under
    the tail.
    """
    sid: int
    text: str = ""
    all_tokens: List[int] = dataclasses.field(default_factory=list)
    node: Optional[object] = None
    tail_page: int = -1
    tail_snap: int = -1
    live: Optional[Request] = None
    turns: int = 0

    @property
    def tail_len(self) -> int:
        return max(len(self.all_tokens) - 1, 0)


class Scheduler:
    """Admission / fairness / step-loop layer over ``EnginePrograms``."""

    def __init__(self, cfg, *, num_slots: int = 4, capacity: int = 512,
                 params=None, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None,
                 retry: Optional[RetryPolicy] = None,
                 default_deadline_s: Optional[float] = None,
                 injector=None, journal_path: Optional[str] = None,
                 watchdog_s: Optional[float] = None,
                 overload: Optional[OverloadPolicy] = None):
        self.engine_cfg = engine_cfg or EngineConfig()
        # device mesh: a degenerate 1×1 host mesh by default, so every
        # single-device path is unchanged; a real mesh (> 1 device)
        # activates the bit-exact "serve" layout (distributed/sharding.py)
        # for params, the cache, the page pool and the snapshot arena
        mesh = self.engine_cfg.mesh
        if mesh is None:
            mesh = make_host_mesh()
        self.mesh = mesh
        self.rules = (sharding.rules_for(mesh, "serve")
                      if mesh.devices.size > 1 else None)
        # fault-tolerance layer (serving/faults.py): bounded retry of
        # transient dispatch faults, deadline default, chaos hooks, and the
        # crash-safe session journal (serving/journal.py)
        self.retry = retry or RetryPolicy()
        self.default_deadline_s = default_deadline_s
        self.injector = injector
        # overload-control layer: None = unbounded admission (the pre-PR-8
        # behaviour); see OverloadPolicy for the knobs
        self.overload = overload
        self.journal = SessionJournal(journal_path)
        self._backoff_rng = random.Random(seed ^ 0x5EED)
        if self.engine_cfg.decode_chunk < 1:
            raise ValueError(
                f"decode_chunk must be >= 1, got {self.engine_cfg.decode_chunk} "
                "(a zero-length chunk makes no progress)")
        mode = self.engine_cfg.cache_mode
        if mode not in ("dense", "paged"):
            raise ValueError(f"cache_mode must be 'dense' or 'paged', got {mode!r}")
        # "paged" resolves per arch family: KV page pool for full-attention
        # archs, per-prefix recurrent-state snapshots for stateful archs
        self.paged = self.snapshots = False
        if mode == "paged":
            ok, why = kvpool.supports_paged(cfg)
            if ok:
                self.paged = True
            else:
                ok2, why2 = kvpool.supports_snapshots(cfg)
                if not ok2:
                    raise ValueError(
                        f"cache_mode='paged' unsupported for {cfg.name}: "
                        f"{why}; {why2}")
                self.snapshots = True
        if self.engine_cfg.spec_len < 0:
            raise ValueError(
                f"spec_len must be >= 0, got {self.engine_cfg.spec_len}")
        self.spec = self.engine_cfg.spec_len > 0
        if self.spec and cfg.modality != "text":
            raise ValueError(
                "speculative decoding needs token-id inputs; "
                f"modality={cfg.modality!r} has no n-gram stream to draft "
                "from")
        # pure full-attention caches tolerate done-row decode writes (same
        # position, same value — idempotent); every other cache family keeps
        # real state that must be frozen for rows sitting a chunk out
        self._freeze_done_rows = not kvpool.supports_paged(cfg)[0]
        bw = max(1, self.engine_cfg.block_w)
        if capacity > bw:
            capacity = -(-capacity // bw) * bw      # align to kernel block
        ps = self.engine_cfg.page_size
        if self.paged or self.snapshots:
            if ps < 1:
                raise ValueError(f"page_size must be >= 1, got {ps}")
        if self.paged:
            capacity = -(-capacity // ps) * ps      # align to page size
        self.cfg = dataclasses.replace(cfg, decode_block_w=bw)
        self.model = Model(self.cfg)
        self.tokenizer = ByteTokenizer(cfg.vocab_size)
        self.num_slots = num_slots
        self.capacity = capacity
        buckets = self.engine_cfg.prefill_buckets
        self.buckets: Tuple[int, ...] = (auto_buckets(capacity)
                                         if buckets is None else
                                         tuple(sorted(buckets)))
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        if self.paged:
            self._bt_width = capacity // ps
            n_pages = self.engine_cfg.num_pages
            if n_pages is None:
                n_pages = 1 + 2 * num_slots * self._bt_width
                if self.rules is not None:
                    # round the auto pool up to the mesh's "data" extent so
                    # the page axis actually shards (device_put refuses
                    # uneven shardings; explicit num_pages is respected and
                    # just replicates the page axis when non-divisible)
                    dsz = self.mesh.shape.get("data", 1)
                    n_pages = -(-n_pages // dsz) * dsz
            # self.cache IS the page pool in paged mode: same pytree
            # structure, batch axis re-purposed as the page axis
            self.cache = kvpool.init_paged_cache(self.cfg, n_pages, ps)
            self.kvpool = kvpool.PagePool(n_pages)
            self.kvpool.injector = injector
            self.radix = RadixTree(ps)
            self._bt_device = None      # cached decode block table (device)
        else:
            self.cache = self.model.init_cache(num_slots, capacity)
            self.kvpool = None
            self.radix = None
        if self.snapshots:
            # snapshot mode: dense per-slot rows + a radix trie whose nodes
            # own rows of a pooled snapshot arena (the model's cache pytree
            # with batch axis = snapshot slots)
            self.radix = RadixTree(ps)
            stride = max(1, self.engine_cfg.snap_stride)
            n_snaps = self.engine_cfg.num_snapshots
            if n_snaps is None:
                n_snaps = 1 + num_slots * (-(-capacity // (ps * stride)) + 2)
                if self.rules is not None:
                    dsz = self.mesh.shape.get("data", 1)
                    n_snaps = -(-n_snaps // dsz) * dsz
            self.snaps = kvpool.SnapshotArena(n_snaps)
            self.snaps.injector = injector
            self.snap_arena = self.model.init_cache(n_snaps, capacity)
        else:
            self.snaps = None
            self.snap_arena = None
        if self.rules is not None:
            # committed placement: params / cache rows / page pool /
            # snapshot arena carry NamedShardings, so every jit dispatch
            # partitions over the mesh instead of replicating. Values are
            # untouched (device_put moves bits); dims that don't divide
            # their mesh axes fall back to replicated per leaf.
            self.params = sharding.shard_put(
                self.params,
                sharding.param_pspecs(self.model.param_axes(), self.rules),
                mesh)
            if self.paged:
                self.cache = kvpool.shard_rows(self.cache, self.cfg,
                                               self.rules, mesh)
            else:
                self.cache = sharding.shard_put(
                    self.cache, sharding.cache_pspecs(self.cfg, self.rules),
                    mesh)
            if self.snap_arena is not None:
                self.snap_arena = kvpool.shard_rows(self.snap_arena, self.cfg,
                                                    self.rules, mesh)
        self.slots = [_Slot() for _ in range(num_slots)]
        self._queue: "collections.deque[Request]" = collections.deque()
        self._rng = jax.random.PRNGKey(seed + 1)   # spec verify/accept key
        self._req_key_base = jax.random.PRNGKey(seed + 2)
        self._next_rid = 0
        self._next_admit = 0
        self._sessions: Dict[int, _SessionState] = {}
        self._next_sid = 0

        # perf counters (benchmarks/*.py read these)
        self._prefill_shapes: set = set()        # 1 jit compile per entry
        self._extend_shapes: set = set()         # ... for extend chunks
        self._decode_syncs = 0                   # blocking pulls in decode
        self._prefill_syncs = 0                  # blocking pulls at admission
        self._decode_tokens = 0
        self._decode_chunks = 0
        self._extend_chunks = 0
        self._truncated_tokens = 0               # dropped at capacity window
        self._truncated_requests = 0
        self._pad_tokens = 0                     # prefill bucket padding waste
        self._prompt_tokens = 0                  # real (unpadded) prompt tokens
        self._prefix_hit_tokens = 0              # served from shared prefixes
        self._draft_tokens = 0                   # spec: tokens proposed
        self._accepted_tokens = 0                # spec: drafts verify accepted
        self._verify_steps = 0                   # spec: verify forwards run
        self._grouped_admissions = 0             # paged/snap: radix-grouped
        self._snap_hits = 0                      # snap: admissions restored
        self._snap_misses = 0                    # ... or prefilled from zero
        self._snap_captures = 0                  # snapshots spliced to arena
        self._sessions_opened = 0                # session/stream counters
        self._session_turns = 0
        self._turn_prefix_hits = 0               # turns admitted off the tail
        self._cancelled = 0
        self._stream_chunks = 0                  # bumped by server streaming
        self._steps = 0                          # engine steps with work
        self._active_slot_sum = 0                # co-batching: Σ active slots
        self._admission_retries = 0              # pool-exhaustion backoffs
        self._dead_lettered = 0                  # requests terminated FAILED
        self._timed_out = 0                      # requests terminated TIMED_OUT
        # overload-control counters / state (OverloadPolicy)
        self._shed = 0                           # requests terminated SHED
        self._preempted = 0                      # running slots preempted
        self._preempt_resumes = 0                # preempted requests resumed
        self._breaker_trips = 0                  # circuit-breaker opens
        self._breaker_failures = 0               # consecutive dead-letters
        self._breaker_open_until = 0.0
        # EWMA service-time model for predictive shedding (s per token);
        # None until the first completion is observed
        self._svc_prefill_tok_s: Optional[float] = None
        self._svc_decode_tok_s: Optional[float] = None

        donate = self.engine_cfg.donate
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.progs = EnginePrograms(
            self.model, self.cfg, self.engine_cfg, capacity=self.capacity,
            num_slots=num_slots, eos_id=self.tokenizer.eos_id,
            freeze_done_rows=self._freeze_done_rows, snapshots=self.snapshots,
            spec=self.spec, donate=donate, injector=injector,
            retry=self.retry, watchdog_s=watchdog_s, rules=self.rules)
        self._zero_key = jnp.zeros((2,), jnp.uint32)
        self._slot_consts = None        # cached (keys, prompt_lens) device
                                        # arrays; rebuilt on membership change

    # ---- public API --------------------------------------------------------
    def enqueue(self, prompt: str, params: Optional[SamplingParams] = None,
                *, session: Optional[int] = None,
                token_ids: Optional[List[int]] = None) -> Request:
        """Queue one request (non-blocking). ``session`` makes it a turn of
        that conversation (one in-flight turn per session); ``token_ids``
        bypasses tokenization (benchmarks replaying exact streams)."""
        p = params or SamplingParams()
        # validate at submit time: a poisoned request must raise a clear
        # ValueError HERE, not fail inside a jit program mid-batch
        if p.max_new_tokens >= self.capacity - 1:
            raise ValueError(
                f"max_new_tokens={p.max_new_tokens} leaves no room for the "
                f"prompt in a capacity-{self.capacity} cache "
                f"(need max_new_tokens <= capacity - 2)")
        if p.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {p.max_new_tokens}")
        if not (p.temperature >= 0.0) or math.isinf(p.temperature):
            raise ValueError(
                f"temperature must be finite and >= 0, got {p.temperature}")
        if p.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {p.top_k}")
        if p.deadline_s is not None and not p.deadline_s > 0:
            raise ValueError(f"deadline_s must be > 0, got {p.deadline_s}")
        if not prompt and token_ids is None:
            raise ValueError(
                "empty prompt (pass token_ids= to replay an exact stream)")
        stop = (p.stop,) if isinstance(p.stop, str) else tuple(p.stop or ())
        self._admission_gate(p)
        self._next_rid += 1
        req = Request(self._next_rid, prompt, p.max_new_tokens, p.temperature,
                      p.top_k, stop=stop, priority=p.priority)
        req.deadline_s = (p.deadline_s if p.deadline_s is not None
                          else self.default_deadline_s)
        req._submit_t = time.perf_counter()
        if token_ids is not None:
            req._ids = list(token_ids)
        # per-request RNG chain: token t of this request samples with
        # fold_in(key, t) — independent of batch composition (programs.py)
        base = (jax.random.PRNGKey(p.seed) if p.seed is not None
                else jax.random.fold_in(self._req_key_base, req.rid))
        req._key = base
        req._key0 = jax.random.fold_in(base, 0)
        if session is not None:
            sess = self._sessions.get(session)
            if sess is None:
                raise ValueError(f"unknown session id {session}")
            if sess.live is not None and not sess.live.finished:
                raise RuntimeError(
                    f"session {session} already has turn rid={sess.live.rid} "
                    "in flight (one turn at a time: turn N+1's prompt "
                    "depends on turn N's output)")
            if req._ids is None:
                if sess.text and prompt.startswith(sess.text) and sess.all_tokens:
                    # token-level continuation: previous stream + new delta —
                    # exact, immune to tokenizer round-trip drift over the
                    # generated tail
                    delta = prompt[len(sess.text):]
                    req._ids = list(sess.all_tokens) + (
                        self.tokenizer.encode(delta, bos=False) if delta
                        else [])
                elif sess.text or sess.all_tokens:
                    # history rewritten: the retained tail no longer applies
                    self._session_reset_tail(sess)
            req._sess = sess
            sess.live = req
            sess.turns += 1
            self._session_turns += 1
        self._insert_by_priority(req)
        return req

    def _admission_gate(self, p: "SamplingParams"):
        """Bounded admission (OverloadPolicy): reject-or-displace BEFORE a
        request object exists, so a refused submit costs the caller one
        typed ``OverloadError`` and the engine nothing."""
        ov = self.overload
        if ov is None:
            return
        now = time.perf_counter()
        if now < self._breaker_open_until:
            raise OverloadError(
                "circuit breaker open for another "
                f"{self._breaker_open_until - now:.3f}s after "
                f"{ov.breaker_threshold} consecutive dispatch dead-letters")
        cap = (ov.class_depth or {}).get(p.priority)
        if cap is not None and sum(1 for r in self._queue
                                   if r.priority == p.priority) >= cap:
            raise OverloadError(
                f"priority-{p.priority} admission queue full "
                f"(class_depth={cap})")
        if (ov.max_queue_depth is not None
                and len(self._queue) >= ov.max_queue_depth):
            # displace the youngest strictly-lower-priority queued request;
            # an arrival that outranks nothing is the one rejected
            victim = None
            for r in reversed(self._queue):
                if r.priority < p.priority:
                    victim = r
                    break
            if victim is None:
                raise OverloadError(
                    f"admission queue full "
                    f"(max_queue_depth={ov.max_queue_depth})")
            self._abort(victim, "shed", ShedError(
                f"rid={victim.rid}: displaced from a full queue "
                f"(depth {ov.max_queue_depth}) by a priority-{p.priority} "
                f"arrival (own priority {victim.priority})"))

    def _insert_by_priority(self, req: Request, *, resumed: bool = False):
        """FIFO within a priority class: insert before the first queued
        request of strictly lower priority. A preempted request re-queues at
        the *front* of its class (``resumed``) — it was admitted before every
        queued peer, so front-of-class preserves true submit order."""
        q = self._queue
        if resumed:
            for i, r in enumerate(q):
                if r.priority <= req.priority:
                    q.insert(i, req)
                    return
            q.append(req)
            return
        if not q or q[-1].priority >= req.priority:
            q.append(req)
            return
        for i, r in enumerate(q):
            if r.priority < req.priority:
                q.insert(i, req)
                return

    def cancel(self, req: Request) -> bool:
        """Cancel a queued or in-flight request: frees its slot, returns its
        private pages to the pool, unpins its radix node, and (for session
        turns) leaves the session's retained tail intact so the turn can be
        retried. Partial output is kept on the request. Returns False if the
        request already finished."""
        return self._abort(req, "cancelled")

    def _abort(self, req: Request, status: str,
               error: Optional[BaseException] = None) -> bool:
        """Terminate a queued or in-flight request in a non-completed
        terminal status (cancelled / timed_out / failed / shed), releasing
        every resource it holds. Deadline expiry, dead-lettering, and load
        shedding reuse the cancellation path, so the leak invariants cover
        all of them."""
        if req.finished:
            return False
        if req in self._queue:
            self._queue.remove(req)
            self._finish_abort(req, status, error)
            return True
        for si, slot in enumerate(self.slots):
            if slot.request is req:
                self._release_slot(si)
                self._finish_abort(req, status, error)
                return True
        return False

    def _release_slot(self, si: int):
        """Capture slot ``si``'s partial output onto its request and free
        everything the slot holds (private pages — the session tail page
        goes back to its session — radix pins). The shared path under
        cancel, deadline expiry, and failure isolation."""
        slot = self.slots[si]
        req = slot.request
        req.output_ids = list(slot.generated)
        req.output_tokens = len(slot.generated)
        req.output_text = self.tokenizer.decode(slot.generated)
        if self.paged:
            priv = list(slot.pages_priv)
            if slot.sess_tail_page >= 0 and req._sess is not None:
                # the tail page's pre-turn positions are untouched
                # (this turn only wrote at/after the tail) — hand it
                # back so the retried turn can still reuse it
                req._sess.tail_page = slot.sess_tail_page
                priv.remove(slot.sess_tail_page)
            self.kvpool.free(priv)
            self.radix.release(slot.node)
            self._bt_device = None
        elif self.snapshots:
            self.radix.release(slot.node)
        self.slots[si] = _Slot()
        self._slot_consts = None

    def _finish_abort(self, req: Request, status: str,
                      error: Optional[BaseException]):
        req.status = status
        req.error = error
        req.cancelled = status in ("cancelled", "timed_out")
        req.finished = True
        req.latency_s = time.perf_counter() - req._submit_t
        if status == "cancelled":
            self._cancelled += 1
        elif status == "timed_out":
            self._timed_out += 1
        elif status == "failed":
            self._dead_lettered += 1
        elif status == "shed":
            self._shed += 1
        if req._sess is not None and req._sess.live is req:
            req._sess.live = None

    # ---- deadlines ---------------------------------------------------------
    def _expire_deadlines(self):
        """Terminate queued and in-flight requests whose deadline elapsed.
        Called at the top of every ``step()`` — i.e. at every chunk sync —
        so an expired request terminates TIMED_OUT within one chunk of its
        deadline, with all resources freed."""
        now = time.perf_counter()
        expired = [r for r in list(self._queue)
                   + [s.request for s in self.slots if s.request is not None]
                   if r.deadline_s is not None
                   and now >= r._submit_t + r.deadline_s]
        for req in expired:
            self._abort(req, "timed_out", DeadlineExceeded(
                f"rid={req.rid}: deadline_s={req.deadline_s} elapsed "
                f"after {now - req._submit_t:.3f}s"))

    # ---- overload control (OverloadPolicy) ---------------------------------
    def _predict_service_s(self, req: Request) -> Optional[float]:
        """Predicted wall-clock to serve ``req`` from admission to finish,
        from the EWMA per-token prefill/decode rates of observed
        completions. None until the engine has decode-rate data."""
        if self._svc_decode_tok_s is None:
            return None
        n_prompt = (len(req._ids) if req._ids is not None
                    else len(req.prompt))       # ByteTokenizer ~1 tok/char
        n_prompt = min(n_prompt, self.capacity)
        budget = req.max_new_tokens - len(req._pre_gen or [])
        return ((self._svc_prefill_tok_s or 0.0) * n_prompt
                + self._svc_decode_tok_s * budget)

    def _note_service(self, req: Request):
        """Fold one completion into the EWMA service-time model."""
        if req.output_tokens and req.decode_s > 0:
            per = req.decode_s / req.output_tokens
            self._svc_decode_tok_s = (
                per if self._svc_decode_tok_s is None
                else 0.8 * self._svc_decode_tok_s + 0.2 * per)
        if req.prompt_tokens and req.prefill_s > 0:
            per = req.prefill_s / req.prompt_tokens
            self._svc_prefill_tok_s = (
                per if self._svc_prefill_tok_s is None
                else 0.8 * self._svc_prefill_tok_s + 0.2 * per)

    def _shed_sweep(self, now: float):
        """Shed queued requests the overload policy says can't be served
        usefully: past their (per-class) age cap, or — predictively — with
        a remaining deadline the EWMA service model says cannot be met.
        Typed, immediate termination beats limping into a timeout."""
        ov = self.overload
        for r in list(self._queue):
            age = now - r._submit_t
            cap = (ov.class_age_s or {}).get(r.priority, ov.max_queue_age_s)
            if cap is not None and age > cap:
                self._abort(r, "shed", ShedError(
                    f"rid={r.rid}: queued {age:.3f}s > age cap {cap}s "
                    f"(priority {r.priority})"))
                continue
            if not ov.shed_on_deadline or r.deadline_s is None:
                continue
            eta = self._predict_service_s(r)
            if eta is None:
                continue
            left = r._submit_t + r.deadline_s - now
            if left < eta * ov.shed_margin:
                self._abort(r, "shed", ShedError(
                    f"rid={r.rid}: remaining deadline {left:.3f}s cannot "
                    f"cover predicted service time {eta:.3f}s "
                    f"(shed_margin={ov.shed_margin})"))

    def _breaker_note(self, ok: bool):
        """Circuit breaker over dispatch dead-letters: ``breaker_threshold``
        consecutive failures open the breaker (submits rejected) for
        ``breaker_cooldown_s``; any successful dispatch resets the count."""
        ov = self.overload
        if ov is None or ov.breaker_threshold <= 0:
            return
        if ok:
            self._breaker_failures = 0
            return
        self._breaker_failures += 1
        if self._breaker_failures >= ov.breaker_threshold:
            self._breaker_open_until = (time.perf_counter()
                                        + ov.breaker_cooldown_s)
            self._breaker_trips += 1
            self._breaker_failures = 0

    def _preempt_for_priority(self, now: float):
        """Priority preemption at the chunk boundary: when an admittable
        queued request outranks a running one and no free slot can serve it,
        the lowest-priority running slot (most budget left on ties — least
        progress thrown away) is preempted and re-queued for bit-identical
        resumption. Strictly-greater priority only, and a resumed request
        keeps its class, so two classes can't ping-pong one slot."""
        ov = self.overload
        if not ov.preempt or not self._queue:
            return
        free = sum(1 for s in self.slots if s.request is None)
        cands = [r for r in self._queue if r._retry_at <= now][free:]
        eos = self.tokenizer.eos_id
        for cand in cands:
            victim, vkey = None, None
            for si, s in enumerate(self.slots):
                r = s.request
                if (r is None or r.priority >= cand.priority
                        or s.remaining <= 0 or s.stopped
                        or s.generated[-1] == eos):   # finalizes this step
                    continue
                k = (r.priority, -s.remaining)
                if vkey is None or k < vkey:
                    victim, vkey = si, k
            if victim is None:
                return
            self._preempt_slot(victim)

    def _preempt_slot(self, si: int):
        """Preempt slot ``si``: release everything it holds (pages / pins /
        the session tail page — the cancel machinery) and re-queue its
        request so a later admission resumes it *bit-identically*: the
        resumed prefill re-processes prompt + the ``k`` already-generated
        tokens (identical KV/state — same tokens, same positions) and the
        RNG chain continues at ``fold_in(key, k)``, exactly the key token
        ``k`` would have been sampled with uninterrupted."""
        slot = self.slots[si]
        req = slot.request
        pre = list(slot.generated)
        req._pre_gen = pre
        req._ids = (slot.token_ids[:req._orig_plen]
                    if slot.token_ids is not None else []) + pre
        req._key0 = jax.random.fold_in(req._key, len(pre))
        self._release_slot(si)
        req.status = "queued"
        req._retry_at = 0.0
        req._admit_attempts = 0
        req.preempted += 1
        self._preempted += 1
        self._insert_by_priority(req, resumed=True)

    # ---- sessions ----------------------------------------------------------
    def open_session(self) -> int:
        self._next_sid += 1
        self._sessions[self._next_sid] = _SessionState(self._next_sid)
        self._sessions_opened += 1
        return self._next_sid

    def close_session(self, sid: int):
        sess = self._sessions.pop(sid, None)
        if sess is None:
            return
        if sess.live is not None and not sess.live.finished:
            self.cancel(sess.live)
        self._session_reset_tail(sess)
        self.journal.drop(sid)

    def restore_session(self, entry: JournalEntry) -> int:
        """Rebuild one journaled session on THIS engine after a teardown:
        opens a fresh session and replays the journaled token stream through
        the normal ``enqueue(token_ids=)`` path — re-prefilling
        ``all_tokens[:-1]`` (the processed prefix) and letting finalize
        re-capture the tail page / tail snapshot at the exact
        end-of-generation boundary, so the next turn's greedy output is
        bit-identical to an uninterrupted server. Dense mode retains no
        device tail; only the token-level bookkeeping is restored (the next
        turn re-prefills, which is already its steady state). Returns the
        new session id."""
        sid = self.open_session()
        sess = self._sessions[sid]
        toks = list(entry.all_tokens)
        if len(toks) >= 2 and (self.paged or self.snapshots):
            req = self.enqueue("", SamplingParams(max_new_tokens=1),
                               session=sid, token_ids=toks[:-1])
            while not req.finished:
                self.step()
        # the replay's sampled continuation token re-derives greedily; pin
        # the journaled stream + text regardless (a temperature turn's
        # sampled token is not part of the processed tail state)
        sess.all_tokens = toks
        sess.text = entry.text
        sess.turns = entry.turns
        self.journal.record(sid, sess.text, sess.all_tokens, sess.turns)
        return sid

    def _session_reset_tail(self, sess: _SessionState):
        """Release everything a session retains between turns."""
        if sess.tail_page >= 0:
            self.kvpool.free([sess.tail_page])
            sess.tail_page = -1
        if sess.tail_snap >= 0:
            self.snaps.free([sess.tail_snap])
            sess.tail_snap = -1
        if sess.node is not None:
            self.radix.release(sess.node)
            sess.node = None
        sess.text = ""
        sess.all_tokens = []

    def _tail_usable(self, req: Request, ids: List[int]) -> int:
        """Token count of the session tail this request can restore (0 = no
        reuse). The actual (possibly truncated) ids must extend the retained
        stream and leave >= 1 suffix token to recompute for first-token
        logits."""
        sess = req._sess
        if sess is None or not sess.all_tokens:
            return 0
        n = sess.tail_len
        if n < 1 or n > len(ids) - 1 or ids[:n] != sess.all_tokens[:n]:
            return 0
        return n

    # ---- fleet routing surface ---------------------------------------------
    def radix_digest(self) -> frozenset:
        """First-block keyspace digest of the radix trie (see
        radix.RadixTree.keyspace_digest) — what this engine exports to a
        fleet router for prefix-affinity placement. Empty in dense mode
        (nothing is shared across requests, so affinity is meaningless)."""
        if self.radix is None:
            return frozenset()
        return self.radix.keyspace_digest()

    def load_score(self) -> float:
        """Routing load estimate: (queued + running requests) × the EWMA
        per-token decode service time from the overload predictor
        (``_svc_decode_tok_s``; 1.0 until the first completion is observed,
        so cold replicas tie and the router's tie-break spreads them). Read
        racily by the fleet router without a pump round-trip — it is a
        heuristic gauge, never a correctness input."""
        depth = len(self._queue) + sum(1 for s in self.slots
                                       if s.request is not None)
        return float(depth) * (self._svc_decode_tok_s or 1.0)

    # ---- stats -------------------------------------------------------------
    def stats(self) -> dict:
        toks = max(self._decode_tokens, 1)
        out = {
            "cache_mode": self.engine_cfg.cache_mode,
            # mesh layout: device count and (axis, size) pairs; "sharded" is
            # False on the default 1×1 host mesh (single-device paths)
            "mesh_devices": int(self.mesh.devices.size),
            "mesh_shape": {k: int(v) for k, v in self.mesh.shape.items()},
            "sharded": self.rules is not None,
            "prefill_compiles": len(self._prefill_shapes),
            "extend_compiles": len(self._extend_shapes),
            "prefill_buckets": list(self.buckets),
            "decode_chunk": self.engine_cfg.decode_chunk,
            "decode_tokens": self._decode_tokens,
            "decode_chunks": self._decode_chunks,
            "extend_chunks": self._extend_chunks,
            "host_syncs": self._decode_syncs,
            "host_syncs_per_token": self._decode_syncs / toks,
            # admission also pulls the first sampled token (once per request,
            # not per token) — reported separately so the decode-path sync
            # rate above stays honest
            "prefill_syncs": self._prefill_syncs,
            # prompt accounting: hard-window truncation (the seed engine
            # dropped these silently) and bucket padding waste (compute spent
            # on pad rows — the knob for tuning prefill_buckets from bench
            # JSON)
            "truncated_requests": self._truncated_requests,
            "truncated_tokens": self._truncated_tokens,
            "prompt_tokens": self._prompt_tokens,
            "prefill_pad_tokens": self._pad_tokens,
            "prefill_pad_frac": self._pad_tokens /
                max(self._pad_tokens + self._prompt_tokens
                    - self._prefix_hit_tokens, 1),
            # speculative decode (all zero when spec_len == 0): drafted vs
            # verify-accepted tokens, and how many verify forwards ran —
            # acceptance_rate is the knob for tuning spec_len / the n-gram
            # range from bench JSON (benchmarks/spec_bench.py)
            "spec_len": self.engine_cfg.spec_len,
            "draft_tokens": self._draft_tokens,
            "accepted_tokens": self._accepted_tokens,
            "acceptance_rate": self._accepted_tokens /
                max(self._draft_tokens, 1),
            "verify_steps": self._verify_steps,
            # session / stream / scheduling counters (the server frontend):
            # turn_prefix_hits = turns admitted off a retained session tail;
            # active_slots_per_step > 1 means concurrent requests actually
            # co-batch inside one engine step
            "sessions_opened": self._sessions_opened,
            "session_turns": self._session_turns,
            "turn_prefix_hits": self._turn_prefix_hits,
            "cancelled_requests": self._cancelled,
            # fault-tolerance counters (serving/faults.py): admission
            # backoffs under pool pressure, requests terminated FAILED /
            # TIMED_OUT, transient dispatch faults retried away, watchdog-
            # flagged slow dispatches, and journaled (recoverable) sessions
            "admission_retries": self._admission_retries,
            "dead_lettered": self._dead_lettered,
            "timed_out": self._timed_out,
            "dispatch_retries": self.progs.dispatch_retries,
            "watchdog_stalls": self.progs.watchdog_stalls,
            "journaled_sessions": len(self.journal),
            "stream_chunks": self._stream_chunks,
            # overload-control counters (OverloadPolicy; all zero without
            # one): typed sheds, chunk-boundary preemptions and their
            # resumed admissions, and circuit-breaker opens
            "shed_requests": self._shed,
            "preemptions": self._preempted,
            "preempt_resumes": self._preempt_resumes,
            "breaker_trips": self._breaker_trips,
            "breaker_open": time.perf_counter() < self._breaker_open_until,
            # EWMA service-time model feeding predictive shedding (s/token;
            # 0.0 until the first completion is observed)
            "ewma_prefill_s_per_tok": self._svc_prefill_tok_s or 0.0,
            "ewma_decode_s_per_tok": self._svc_decode_tok_s or 0.0,
            # live-work gauges (not counters): a drained server shows 0/0 —
            # the FAME workflow gate asserts every handle reached a terminal
            # status with nothing stranded in the queue or a slot
            "queued_requests": len(self._queue),
            "live_requests": sum(1 for s in self.slots
                                 if s.request is not None),
            # queue-shape gauges: depth per priority class and the oldest
            # queued request's wait so far (overload dashboards / gates)
            "queue_depth_by_priority": dict(collections.Counter(
                r.priority for r in self._queue)),
            "queue_age_max_s": max(
                (time.perf_counter() - r._submit_t for r in self._queue),
                default=0.0),
            "engine_steps": self._steps,
            "active_slots_per_step": self._active_slot_sum /
                max(self._steps, 1),
        }
        if self.paged or self.snapshots:
            out.update({
                "page_size": self.engine_cfg.page_size,
                "radix_nodes": self.radix.num_nodes,
                # the headline: prompt tokens served straight from shared
                # pages / restored state snapshots instead of re-prefilled
                "prefix_hit_tokens": self._prefix_hit_tokens,
                "prefix_hit_rate": self._prefix_hit_tokens /
                    max(self._prompt_tokens, 1),
                # queued requests admitted in the same engine step as an
                # earlier request sharing their first radix block (the
                # shared pages/snapshots are matched while still pinned/hot)
                "grouped_admissions": self._grouped_admissions,
            })
        if self.paged:
            out.update({
                "pages_total": self.kvpool.num_pages,
                "pages_free": self.kvpool.num_free,
                "pages_peak_in_use": self.kvpool.peak_in_use,
                "radix_evicted_pages": self.radix.evicted_pages,
            })
        if self.snapshots:
            out.update({
                # per-prefix recurrent-state snapshot arena: hits restore a
                # boundary state instead of re-prefilling; misses prefill
                # from scratch; evictions are LRU trie leaves reclaimed when
                # the arena fills (tune num_snapshots / snap_stride from
                # these)
                "snapshots_total": self.snaps.num_snaps,
                "snapshots_free": self.snaps.num_free,
                "snapshots_peak_in_use": self.snaps.peak_in_use,
                "snapshot_hits": self._snap_hits,
                "snapshot_misses": self._snap_misses,
                "snapshot_captures": self._snap_captures,
                "snapshot_evictions": self.radix.evicted_snaps,
            })
        return out

    # ---- engine loop: admission --------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n                        # exact-length (legacy) mode

    def _chunk_plan(self, n: int, start: int) -> List[Tuple[int, int, int]]:
        """Split ``n`` prompt tokens beginning at position ``start`` into
        prefill chunks: (offset, real_len, padded_len) triples. All chunks
        but the last are exactly the largest bucket; the last is bucketed
        (and clamped so the padded write never overruns capacity)."""
        mb = max(self.buckets) if self.buckets else n
        plan = []
        off = 0
        while off < n:
            rest = n - off
            if rest > mb:
                plan.append((off, mb, mb))
            else:
                padded = min(self._bucket_for(rest),
                             self.capacity - (start + off))
                plan.append((off, rest, padded))
            off += plan[-1][1]
        return plan

    def _chunk_batch(self, ids: List[int], start: int, padded: int):
        """Device token/position arrays for one right-padded prefill chunk."""
        padded_ids = ids + [self.tokenizer.pad_id] * (padded - len(ids))
        tokens = jnp.asarray([padded_ids], jnp.int32)
        positions = start + jnp.arange(padded, dtype=jnp.int32)[None, :]
        if self.cfg.modality == "audio_frames":
            # modality stub: frame embeddings stand in for token ids
            tokens = jax.nn.one_hot(tokens % self.cfg.d_model, self.cfg.d_model,
                                    dtype=jnp.dtype(self.cfg.dtype))
        return tokens, positions

    def _req_budget(self, req: Request) -> int:
        """Remaining output budget: max_new_tokens, minus tokens already
        generated before a preemption (they re-prefill, not re-generate)."""
        return req.max_new_tokens - len(req._pre_gen or [])

    def _encode_prompt(self, req: Request) -> List[int]:
        """Tokenize + clamp to the capacity window, counting what was cut
        (the seed engine dropped tokens here with no trace at all). A
        preempted request's window grows by its pre-generated token count,
        so the resume never truncates deeper than the original admission."""
        window = self.capacity - self._req_budget(req) - 1   # >= 1 (enqueue
        if req._ids is None:                                 # guard)
            req._ids = self.tokenizer.encode(req.prompt)
        full = req._ids
        ids = full[-window:]
        req.truncated_tokens = len(full) - len(ids)
        if req.truncated_tokens:
            self._truncated_tokens += req.truncated_tokens
            self._truncated_requests += 1
        req.prompt_tokens = len(ids)
        self._prompt_tokens += len(ids)
        return ids

    def _uncount_prompt(self, req: Request, ids: List[int]):
        """Roll back _encode_prompt's counters when admission fails and the
        request stays at the queue head."""
        self._prompt_tokens -= len(ids)
        if req.truncated_tokens:
            self._truncated_tokens -= req.truncated_tokens
            self._truncated_requests -= 1

    def _prefill_span(self, si: int, req: Request, ids: List[int],
                      start: int, end: int, *, sample: bool):
        """Prefill ``ids[start:end]`` into slot ``si`` in bucketed chunks.

        ``start == 0`` opens with the bucketed prefill (fresh cache row — it
        always unembeds one position and samples; a non-final span discards
        that token); every other chunk is an ``extend`` continuation against
        the already-filled row (restored snapshot / session tail included)
        that unembeds + samples only when it is the last chunk and
        ``sample``. Returns the last chunk's sampled token.
        """
        plan = self._chunk_plan(end - start, start)
        tok = None
        for ci, (off, real, padded) in enumerate(plan):
            o = start + off
            tokens, positions = self._chunk_batch(ids[o:o + real], o, padded)
            self._pad_tokens += padded - real
            last = ci == len(plan) - 1
            if o == 0:
                self._prefill_shapes.add((padded, self.cfg.modality))
                self.cache, t = self.progs.prefill(
                    self.params, self.cache, tokens, positions,
                    jnp.int32(si), jnp.int32(real), req._key0,
                    jnp.float32(req.temperature), jnp.int32(req.top_k))
            else:
                self._extend_shapes.add((padded, self.cfg.modality))
                self._extend_chunks += 1
                self.cache, t = self.progs.extend(
                    self.params, self.cache, tokens, positions,
                    jnp.int32(si), jnp.int32(o), jnp.int32(real), req._key0,
                    jnp.float32(req.temperature), jnp.int32(req.top_k),
                    sample=sample and last)
            if last:
                tok = t
        return tok

    def _activate(self, si: int, slot: _Slot, req: Request, ids: List[int],
                  first) -> None:
        """Common post-prefill slot activation + the one admission sync.

        A preempt-resume (``req._pre_gen``) re-enters here with ``ids`` =
        original prompt + pre-generated tokens; ``prompt_len`` stays the
        *original* prompt length so the in-jit sample-count math
        (``cnts = cache_len - prompt_len + 1``) continues the RNG chain at
        exactly the token index the preemption interrupted."""
        pre = req._pre_gen or []
        slot.request = req
        slot.cache_len = len(ids)
        slot.prompt_len = len(ids) - len(pre)
        slot.remaining = req.max_new_tokens - len(pre) - 1
        slot.generated = list(pre) + [int(first)]         # one host sync
        if pre:
            self._preempt_resumes += 1
        else:
            req._orig_plen = len(ids)
        req._pre_gen = None
        if req.first_token_s == 0.0:
            req.first_token_s = time.perf_counter() - req._submit_t
        req.status = "running"
        self._arm_spec(slot, ids)
        self._slot_consts = None        # slot membership changed
        self._prefill_syncs += 1

    def _admit_dense(self, si: int, slot: _Slot, req: Request):
        ids = self._encode_prompt(req)
        try:
            first = self._prefill_span(si, req, ids, 0, len(ids), sample=True)
        except Exception:
            # failure isolation: nothing allocated yet — the partially
            # written cache row is fully overwritten by the next admission
            self._uncount_prompt(req, ids)
            raise
        self._activate(si, slot, req, ids, first)
        slot.token_ids = ids        # sessions track the exact token stream
                                    # (dense mode reuses nothing, but turn
                                    # continuation must still be token-exact)
        return True

    def _admit_paged(self, si: int, slot: _Slot, req: Request):
        """Paged admission: radix-match the prompt, reserve pages, prefill
        only the un-matched suffix. A session turn that extends its retained
        conversation additionally reuses the session's partial tail page and
        starts at the exact (non-block-aligned) position the conversation
        left off. Returns False (request stays queued) when the pool can't
        supply pages even after LRU eviction."""
        ids = self._encode_prompt(req)
        ps = self.engine_cfg.page_size
        sess = req._sess
        # always recompute at least the last prompt token (its logits seed
        # the first sampled token), so cap the usable match one token short
        shared, node = self.radix.match(ids[:len(ids) - 1])
        tail_len = self._tail_usable(req, ids)
        # the tail page only adjoins gap-free if the radix (pinned by the
        # session since last turn) still covers every complete block below it
        use_tail = (tail_len > len(shared) * ps and sess.tail_page >= 0
                    and len(shared) == tail_len // ps)
        prefix_len = tail_len if use_tail else len(shared) * ps
        total_pages = -(-min(len(ids) + self._req_budget(req) + 1,
                             self.capacity) // ps)
        if total_pages > self.kvpool.num_pages - self.kvpool.reserved:
            # can NEVER fit, even with every page free: dead-letter instead
            # of spinning the admission loop (or crashing the pump)
            self.radix.release(node)
            self._uncount_prompt(req, ids)
            raise RequestFault(
                f"paged KV pool too small: request rid={req.rid} needs "
                f"{total_pages} pages but the pool can ever free at most "
                f"{self.kvpool.num_pages - self.kvpool.reserved} "
                f"(num_pages={self.kvpool.num_pages}, page_size={ps})")
        n_have = len(shared) + (1 if use_tail else 0)
        priv = self.kvpool.alloc(total_pages - n_have)
        if priv is None:
            freed = self.radix.evict(total_pages - n_have
                                     - self.kvpool.num_free)
            self.kvpool.free(freed)
            priv = self.kvpool.alloc(total_pages - n_have)
        if priv is None:
            self.radix.release(node)
            # un-count this attempt; the request stays at the queue head
            self._uncount_prompt(req, ids)
            return False
        if use_tail:
            # the tail page transfers to this request's private chain; on
            # cancel it goes back to the session, on finalize it re-enters
            # the normal adopt-or-retail flow
            slot.sess_tail_page = sess.tail_page
            priv = [sess.tail_page] + priv
            sess.tail_page = -1
        hit_turn = bool(tail_len and prefix_len >= tail_len)
        if hit_turn:
            # the whole retained conversation was served from reuse — the
            # session tail, or a radix path another request drove deeper
            self._turn_prefix_hits += 1
        req.prefix_hit_tokens = prefix_len
        self._prefix_hit_tokens += prefix_len
        bt = kvpool.block_table_array([shared + priv], self._bt_width)
        first = None
        plan = self._chunk_plan(len(ids) - prefix_len, prefix_len)
        try:
            for ci, (off, real, padded) in enumerate(plan):
                start = prefix_len + off
                tokens, positions = self._chunk_batch(
                    ids[start:start + real], start, padded)
                self._pad_tokens += padded - real
                self._extend_shapes.add((padded, self.cfg.modality))
                self._extend_chunks += 1
                self.cache, tok = self.progs.extend_paged(
                    self.params, self.cache, tokens, positions, bt,
                    jnp.int32(start), jnp.int32(real), req._key0,
                    jnp.float32(req.temperature), jnp.int32(req.top_k),
                    sample=ci == len(plan) - 1)
                if ci == len(plan) - 1:
                    first = tok
        except Exception:
            # failure isolation: this request never activated — give the
            # tail page back to its session, return every reserved page,
            # unpin the trie, and roll the admission counters back so the
            # exactly-once ownership invariant holds on the fault path too
            if slot.sess_tail_page >= 0:
                sess.tail_page = slot.sess_tail_page
                priv.remove(slot.sess_tail_page)
                slot.sess_tail_page = -1
            self.kvpool.free(priv)
            self.radix.release(node)
            if hit_turn:
                self._turn_prefix_hits -= 1
            self._prefix_hit_tokens -= prefix_len
            req.prefix_hit_tokens = 0
            self._uncount_prompt(req, ids)
            raise
        self._activate(si, slot, req, ids, first)
        slot.token_ids = ids
        slot.pages_shared = shared
        slot.pages_priv = priv
        slot.node = node
        self._bt_device = None          # slot membership changed
        self._group_queue(ids, req)
        return True

    def _capture_snapshot(self, si: int) -> int:
        """Splice slot ``si``'s current state into a fresh arena row.
        Returns the slot id, or -1 when the arena stays full even after LRU
        trie eviction (every row backs a pinned path) — the capture is then
        skipped; correctness is untouched, only future hit depth."""
        sid = self.snaps.alloc()
        if sid is None:
            self.snaps.free(self.radix.evict_snaps(1))
            sid = self.snaps.alloc()
        if sid is None:
            return -1
        try:
            self.snap_arena = self.progs.snap_capture(self.snap_arena,
                                                      self.cache,
                                                      jnp.int32(sid),
                                                      jnp.int32(si))
        except Exception:
            self.snaps.free([sid])      # exactly-once: reclaim the fresh row
            raise
        self._snap_captures += 1
        return sid

    def _admit_snap(self, si: int, slot: _Slot, req: Request):
        """Snapshot-mode admission (stateful archs under cache_mode="paged"):
        radix-match the prompt, restore the nearest per-prefix state
        snapshot into the slot — or, for a session turn extending its
        conversation, the session's end-of-generation tail snapshot at its
        exact non-block-aligned length — and prefill only the suffix,
        capturing new snapshots at every ``snap_stride``-block boundary
        along the way and adopting them into the trie immediately, so the
        rest of THIS engine step's grouped admissions already reuse them.
        Never fails: snapshots take no pages, and a full arena only skips
        captures."""
        ids = self._encode_prompt(req)
        ps = self.engine_cfg.page_size
        sess = req._sess
        # always recompute at least the last prompt token (its logits seed
        # the first sampled token), so cap the usable match one token short
        _, node = self.radix.match(ids[:len(ids) - 1])
        new_snaps = {}
        try:
            sid, sblocks = self.radix.nearest_snapshot(node)
            restore = sblocks * ps
            tail_len = self._tail_usable(req, ids)
            if tail_len > restore and sess.tail_snap >= 0:
                # session tail beats the deepest block-aligned trie snapshot
                self.cache = self.progs.snap_restore(
                    self.cache, self.snap_arena, jnp.int32(sess.tail_snap),
                    jnp.int32(si))
                restore = tail_len
                self._snap_hits += 1
            elif sid >= 0:
                self.cache = self.progs.snap_restore(
                    self.cache, self.snap_arena, jnp.int32(sid),
                    jnp.int32(si))
                self._snap_hits += 1
            else:
                self._snap_misses += 1
            if tail_len and restore >= tail_len:
                self._turn_prefix_hits += 1
            req.prefix_hit_tokens = restore
            self._prefix_hit_tokens += restore
            stride = ps * max(1, self.engine_cfg.snap_stride)
            bounds = set(range((restore // stride + 1) * stride,
                               len(ids) + 1, stride))
            pos, first = restore, None
            for end in sorted(bounds | {len(ids)}):
                first = self._prefill_span(si, req, ids, pos, end,
                                           sample=end == len(ids))
                if end in bounds:
                    s = self._capture_snapshot(si)
                    if s >= 0:
                        new_snaps[end // ps] = s
                pos = end
            if new_snaps:
                hi = max(new_snaps) * ps
                self.snaps.free(self.radix.insert_snaps(ids[:hi], new_snaps))
        except Exception:
            # failure isolation: unpin the trie, return captured-but-not-
            # yet-inserted snapshots to the arena, roll back the counters —
            # exactly-once snapshot ownership holds on the fault path too
            self.radix.release(node)
            self.snaps.free(list(new_snaps.values()))
            self._prefix_hit_tokens -= req.prefix_hit_tokens
            req.prefix_hit_tokens = 0
            self._uncount_prompt(req, ids)
            raise
        self._activate(si, slot, req, ids, first)
        slot.token_ids = ids
        slot.node = node
        self._group_queue(ids, req)
        return True

    def _arm_spec(self, slot: _Slot, ids: List[int]):
        """Index the request's context for the n-gram drafter (prompt + the
        first sampled token; decode/verify commits extend it)."""
        if not self.spec:
            return
        # ids + the newly sampled token; a preempt-resume's pre-generated
        # tokens are already inside ids, so index only the last sample
        slot.drafter = NgramDrafter(ids + slot.generated[-1:],
                                    n_min=self.engine_cfg.spec_ngram_min,
                                    n_max=self.engine_cfg.spec_ngram_max)
        slot.spec_on = True

    def _group_queue(self, ids: List[int], req: Request):
        """Radix-aware admission batching (paged): stable-move queued
        requests whose (truncated) prompt shares the just-admitted prompt's
        first radix block to the queue front, so the remaining free slots of
        THIS engine step admit them while the shared prefix pages are pinned
        and hot — N agents sharing a system prompt prefill it once and join
        the same decode batch. FIFO order survives within the group and the
        remainder (a grouped request may jump a higher priority class for
        this one step — the shared-prefix locality win is worth it)."""
        ps = self.engine_cfg.page_size
        # ``req`` is the request being admitted right now (still queued until
        # _admit removes it; with admission backoff it need not be the head)
        others = [r for r in self._queue if r is not req]
        if len(ids) < ps or not others:
            return
        head = tuple(ids[:ps])
        grouped, rest = [], []
        for r in others:
            if r._ids is None:
                r._ids = self.tokenizer.encode(r.prompt)
            rids = r._ids[-(self.capacity - r.max_new_tokens - 1):]
            if len(rids) >= ps and tuple(rids[:ps]) == head:
                r._grouped = True
                grouped.append(r)
            else:
                rest.append(r)
        if grouped:
            self._queue = collections.deque([req] + grouped + rest)

    def _next_admittable(self, now: float) -> Optional[Request]:
        """First queued request not sitting out an admission backoff —
        priority/FIFO order is the queue order, so the head-of-line request
        still admits first whenever it is eligible."""
        for r in self._queue:
            if r._retry_at <= now:
                return r
        return None

    def _admit(self):
        """Prefill queued requests into free slots (continuous batching).

        Admission is FIFO within priority classes, with two fault-layer
        behaviours (serving/faults.py):

        * **backoff + starvation guard**: when the paged pool can't cover a
          request even after LRU eviction, the request backs off
          (exponential + jitter per ``RetryPolicy``) instead of blocking the
          whole round — the next admittable candidate gets a shot at the
          slot, so a burst of small requests keeps flowing around a large
          head-of-line request. The backed-off request keeps its queue
          position and admits first again the moment its backoff elapses.
        * **dead-lettering**: a request whose admission *faults* (injected
          ``RequestFault``, a page demand the pool can never satisfy, or —
          with nothing active to ever free pages — retries exhausted) is
          terminated FAILED with the error on the request, instead of
          crashing the engine pump.
        """
        admit = (self._admit_paged if self.paged else
                 self._admit_snap if self.snapshots else
                 self._admit_dense)
        for si, slot in enumerate(self.slots):
            if slot.request is not None:
                continue
            while True:          # candidates until one admits or none left
                now = time.perf_counter()
                req = self._next_admittable(now)
                if req is None:
                    break
                t0 = time.perf_counter()
                try:
                    admitted = admit(si, slot, req)
                except (RequestFault, DeadLetterError) as e:
                    # failure isolation: only this request dies; the slot
                    # is still free for the next candidate
                    self._queue.remove(req)
                    self._finish_abort(req, "failed", e)
                    if isinstance(e, DeadLetterError):
                        self._breaker_note(False)
                    continue
                if not admitted:
                    req._admit_attempts += 1
                    self._admission_retries += 1
                    if (req._admit_attempts >= self.retry.max_attempts
                            and not self._active()):
                        # nothing running will ever free pages for it:
                        # waiting longer cannot help — dead-letter
                        self._queue.remove(req)
                        self._finish_abort(req, "failed", DeadLetterError(
                            f"rid={req.rid}: admission failed "
                            f"{req._admit_attempts} times with no active "
                            f"requests to free pool capacity"))
                        continue
                    req._retry_at = now + self.retry.delay(
                        req._admit_attempts, self._backoff_rng)
                    continue
                self._queue.remove(req)
                if req._grouped:
                    self._grouped_admissions += 1
                    req._grouped = False
                req._admit_attempts = 0
                req.admit_index = self._next_admit
                self._next_admit += 1
                req.prefill_s += time.perf_counter() - t0
                break
        # grouping credit is same-step only: a sharer still queued when the
        # round ends admits later on its own (the pinned pages may be gone)
        for r in self._queue:
            r._grouped = False

    def _active(self):
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    # ---- stop sequences ----------------------------------------------------
    def _apply_stop(self, slot: _Slot) -> bool:
        """Host-side stop-sequence check at the per-chunk sync: halt at the
        first token whose decoded prefix contains a stop string and trim the
        tokens after it from the result (token granularity — the stop may
        end mid-token). The full decoded text is searched, so a stop split
        across a chunk boundary is caught the moment its last piece lands."""
        req = slot.request
        if not req.stop or slot.stopped:
            return slot.stopped
        text = self.tokenizer.decode(slot.generated)
        if not any(s in text for s in req.stop):
            return False
        for n in range(1, len(slot.generated) + 1):
            t = self.tokenizer.decode(slot.generated[:n])
            if any(s in t for s in req.stop):
                slot.generated = slot.generated[:n]
                slot.stopped = True
                return True
        return False                                      # unreachable

    # ---- finalize ----------------------------------------------------------
    def _finalize(self, si: int):
        slot = self.slots[si]
        req = slot.request
        sess = req._sess
        req.output_ids = list(slot.generated)
        req.output_tokens = len(slot.generated)
        req.output_text = self.tokenizer.decode(slot.generated)
        req.latency_s = time.perf_counter() - req._submit_t
        # token_ids[:prompt_len] is the admitted prompt; for a preempt-
        # resumed slot token_ids additionally carries the re-prefilled
        # pre-generated tokens, which slot.generated already repeats
        all_tokens = (slot.token_ids[:slot.prompt_len]
                      if slot.token_ids is not None else []) + slot.generated
        # positions the cache truly covers for the *trimmed* output (the
        # final generated token is sampled but never processed; a stop trim
        # shrinks this below slot.cache_len)
        kv_cover = max(len(all_tokens) - 1, 0)
        if self.paged:
            # donate the finished sequence's complete pages to the radix tree
            # (prompt + generated tokens: the next agent turn's prompt embeds
            # this whole conversation, so it will match deep), free the rest
            ps = self.engine_cfg.page_size
            n_complete = kv_cover // ps
            bt_pages = slot.pages_shared + slot.pages_priv
            rejected = self.radix.insert(all_tokens[:n_complete * ps],
                                         bt_pages[:n_complete])
            if sess is not None and not req.cancelled:
                leftover = rejected + bt_pages[n_complete:]
                tail_page = -1
                if kv_cover % ps and bt_pages[n_complete] not in rejected:
                    # the partial tail page: positions past the last block
                    # boundary, generated tokens included — the session keeps
                    # it so the next turn restores at the exact end of this
                    # one instead of the last page boundary
                    tail_page = bt_pages[n_complete]
                    leftover = [p for p in leftover if p != tail_page]
                self.kvpool.free(leftover)
                # re-pin the trie path under the (possibly deeper) complete
                # prefix so eviction can't open a gap below the tail
                _, new_node = self.radix.match(all_tokens[:n_complete * ps])
                self.radix.release(slot.node)
                if sess.node is not None:
                    self.radix.release(sess.node)
                if sess.tail_page >= 0:          # superseded tail
                    self.kvpool.free([sess.tail_page])
                sess.node = new_node
                sess.tail_page = tail_page
            else:
                self.kvpool.free(rejected + bt_pages[n_complete:])
                self.radix.release(slot.node)
            self._bt_device = None      # slot membership changed
        elif self.snapshots:
            # prefix snapshots were adopted into the trie at admission; a
            # session turn additionally captures the end-of-generation state
            # at its exact (non-block-aligned) length into a session-owned
            # arena row — the trie can't index it, the session can
            if sess is not None and not req.cancelled:
                if slot.stopped:
                    new_snap = -1
                else:
                    try:
                        new_snap = self._capture_snapshot(si)
                    except Exception:
                        # a faulted tail capture degrades to a skipped one
                        # (pure optimization: the next turn re-prefills) —
                        # it must not crash the pump at finalize
                        new_snap = -1
                if sess.tail_snap >= 0:
                    self.snaps.free([sess.tail_snap])
                sess.tail_snap = new_snap
                # transfer the admission pin: it covers the prompt path the
                # next turn will re-match
                if sess.node is not None:
                    self.radix.release(sess.node)
                sess.node = slot.node
            else:
                self.radix.release(slot.node)
        if sess is not None and not req.cancelled:
            # a stop trim / EOS-truncated spec accept leaves device state
            # past the kept tokens: the token stream is still exact
            # (kv_cover shrank with it), but the snapshot capture above is
            # skipped since the state ran ahead (KV pages are per-position,
            # so the paged tail page stays valid either way)
            sess.all_tokens = all_tokens
            sess.text = req.prompt + req.output_text
            if sess.live is req:
                sess.live = None
            # crash-safe journal: the token-level state a fresh server needs
            # to rebuild this session's tail (restore_session)
            self.journal.record(sess.sid, sess.text, sess.all_tokens,
                                sess.turns)
        req.status = "completed"
        req.finished = True
        self._note_service(req)
        self.slots[si] = _Slot()

    # ---- speculative decode pass -------------------------------------------
    def _spec_pass(self, active) -> set:
        """One speculative verify pass, interleaved with the chunked-decode
        loop: slots whose drafter has a proposal verify it this step; the
        returned set sits out the decode chunk. Falls back to plain chunked
        decode (empty set) when no slot has a draft, so non-copyable
        workloads pay nothing but the host-side n-gram lookups."""
        eos = self.tokenizer.eos_id
        live = []
        for i in active:
            s = self.slots[i]
            # same conditions the decode loop's entry done-mask would catch
            if (s.remaining <= 0 or s.cache_len >= self.capacity - 1
                    or s.generated[-1] == eos):
                self._finalize(i)
                continue
            live.append(i)
        if not live:
            return set(active)
        drafts = {}
        for i in live:
            s = self.slots[i]
            d = []
            if s.spec_on:
                # the +1 correction/bonus token must fit the budget and the
                # capacity window, and draft writes must stay in bounds
                cap = min(self.engine_cfg.spec_len, s.remaining - 1,
                          self.capacity - 2 - s.cache_len)
                if cap > 0:
                    d = s.drafter.draft(cap)
            drafts[i] = d
        drafted = [i for i in live if drafts[i]]
        if not drafted:
            return set()
        # only drafted slots verify; the rest keep the chunked decode loop
        # (a disabled or draftless slot must not degrade to one-token steps)
        try:
            self._spec_step_batched(drafted, drafts)
        except Exception as e:
            # failure isolation: only the drafted slots die; undrafted
            # co-batched slots still run their decode chunk this step
            self._fail_slots(drafted, e)
        return set(drafted)

    def _spec_step_batched(self, live, drafts):
        """ONE jit'd verify forward scores every drafted slot's proposal at
        once, for every arch (rows of undrafted slots carry lens=0 — no
        reads, no writes, no commits). Rollback: linear full-attention K/V
        is masked by cache position until overwritten; recurrent / conv /
        xLSTM / ring-KV state rewinds to each row's accepted length inside
        the same jit (``model.verify_commit``)."""
        t0 = time.perf_counter()
        S = self.engine_cfg.spec_len + 1
        tok_rows = [[0] * S for _ in range(self.num_slots)]
        lens = [0] * self.num_slots
        for i in live:
            s = self.slots[i]
            row = [s.generated[-1]] + drafts[i]
            lens[i] = len(row)
            tok_rows[i][:len(row)] = row
        tokens = jnp.asarray(tok_rows, jnp.int32)
        lens_a = jnp.asarray(lens, jnp.int32)
        clens = jnp.asarray([s.cache_len for s in self.slots], jnp.int32)
        # the same greedy/temps/top-k static specialization as the decode loop
        sampling = any(self.slots[i].request.temperature > 0.0 for i in live)
        temps = (jnp.asarray([s.request.temperature if s.request else 0.0
                              for s in self.slots], jnp.float32)
                 if sampling else None)
        top_ks = (jnp.asarray([s.request.top_k if s.request else 0
                               for s in self.slots], jnp.int32)
                  if sampling and any(self.slots[i].request.top_k > 0
                                      for i in live)
                  else None)
        self._rng, k = jax.random.split(self._rng)
        bt = self._decode_block_tables()
        self.cache, out_tok, out_len = self.progs.verify(
            self.params, self.cache, tokens, clens, lens_a, temps, top_ks,
            k, bt)
        # the ONE host sync of the verify step
        out_tok, out_len = jax.device_get((out_tok, out_len))
        self._breaker_note(True)
        self._decode_syncs += 1
        self._verify_steps += 1
        dt = time.perf_counter() - t0
        for i in live:
            self._commit_spec(i, drafts[i], out_tok[i], int(out_len[i]),
                              dt / len(live))

    def _commit_spec(self, si, draft, out_row, n, dt):
        """Commit one slot's verify outcome: n = accepted drafts + 1
        correction/bonus token, truncated at the first EOS."""
        slot = self.slots[si]
        eos = self.tokenizer.eos_id
        emitted = [int(t) for t in out_row[:n]]
        for j, t in enumerate(emitted):
            if t == eos:
                emitted = emitted[:j + 1]
                break
        if len(emitted) < n:
            # accepted drafts past the EOS were already committed into the
            # device state (verify_commit rewinds to the accepted length,
            # not the EOS) — the state now runs ahead of the kept tokens,
            # exactly like a stop trim: a session tail snapshot captured
            # from it would corrupt the next turn, so flag the slot
            slot.stopped = True
        slot.generated.extend(emitted)
        slot.drafter.extend(emitted)
        slot.cache_len += len(emitted)
        slot.remaining -= len(emitted)
        slot.spec_drafted += len(draft)
        slot.spec_accepted += n - 1
        self._draft_tokens += len(draft)
        self._accepted_tokens += n - 1
        self._decode_tokens += len(emitted)
        slot.request.decode_s += dt
        ecfg = self.engine_cfg
        if (slot.spec_on and slot.spec_drafted >= ecfg.spec_warmup
                and slot.spec_accepted <
                ecfg.spec_min_accept * slot.spec_drafted):
            slot.spec_on = False        # this request isn't n-gram-predictable
        stopped = self._apply_stop(slot)
        if (stopped or slot.remaining <= 0 or slot.generated[-1] == eos
                or slot.cache_len >= self.capacity - 1):
            self._finalize(si)

    # ---- engine step --------------------------------------------------------
    def _decode_block_tables(self):
        """Per-slot block tables for the decode/verify jits (paged mode):
        the table only changes when slot membership does — cached on device
        between chunks; empty slots point at the trash page."""
        if not self.paged:
            return None
        if self._bt_device is None:
            self._bt_device = kvpool.block_table_array(
                [(s.pages_shared + s.pages_priv) if s.request else []
                 for s in self.slots], self._bt_width)
        return self._bt_device

    def _fail_slots(self, indices, exc: BaseException):
        """Failure isolation: terminate the requests in these slots FAILED,
        freeing everything they hold; co-batched requests in other slots are
        untouched. Injected faults raise *before* dispatch (programs._run),
        so the shared cache was not consumed and the survivors' state is
        exactly what it was before the faulted call."""
        for si in indices:
            if self.slots[si].request is None:
                continue
            req = self.slots[si].request
            self._release_slot(si)
            self._finish_abort(req, "failed", exc)
        if isinstance(exc, DeadLetterError):
            self._breaker_note(False)

    def step(self):
        """One engine iteration: expire deadlines, run the overload policy
        (shed sweep + priority preemption at this chunk boundary), admit,
        then one speculative verify pass for slots with drafts (when spec
        is on) and/or one chunked decode for the rest."""
        self._expire_deadlines()
        if self.overload is not None:
            now = time.perf_counter()
            self._shed_sweep(now)
            self._preempt_for_priority(now)
        self._admit()
        active = self._active()
        if not active:
            if self._queue:
                # every queued request is in admission backoff: sleep until
                # the earliest retry so run_until_drained / pump loops don't
                # hot-spin the admission path
                wait = (min(r._retry_at for r in self._queue)
                        - time.perf_counter())
                if wait > 0:
                    time.sleep(min(wait, 0.05))
            return False
        # co-batching telemetry: how many requests actually share this step
        self._steps += 1
        self._active_slot_sum += len(active)
        handled = self._spec_pass(active) if self.spec else set()
        rest = [i for i in self._active() if i not in handled]
        if not rest:
            return True
        t0 = time.perf_counter()
        last = jnp.asarray([s.generated[-1] if s.request else 0
                            for s in self.slots], jnp.int32)
        clens = jnp.asarray([s.cache_len for s in self.slots], jnp.int32)
        rem = jnp.asarray([s.remaining for s in self.slots], jnp.int32)
        # spec-handled slots sit this chunk out via the done mask (they
        # already advanced up to spec_len+1 tokens this step)
        done = jnp.asarray([i in handled or s.request is None
                            or s.remaining <= 0
                            or s.cache_len >= self.capacity - 1
                            or s.generated[-1] == self.tokenizer.eos_id
                            for i, s in enumerate(self.slots)], bool)
        # static specialization: an all-greedy batch (the common agent case)
        # compiles a loop body with no RNG fold / categorical / top-k sort —
        # jit re-specializes on the None-vs-array structure, so at most three
        # decode variants ever compile (greedy / temps / temps+top-k)
        sampling = any(s.request.temperature > 0.0
                       for s in self.slots if s.request)
        temps = (jnp.asarray([s.request.temperature if s.request else 0.0
                              for s in self.slots], jnp.float32)
                 if sampling else None)
        top_ks = (jnp.asarray([s.request.top_k if s.request else 0
                               for s in self.slots], jnp.int32)
                  if sampling and any(s.request.top_k > 0
                                      for s in self.slots if s.request)
                  else None)
        # per-request RNG chains: row b samples its t-th token with
        # fold_in(keys[b], t), t derived in-jit from cache_lens and the
        # prompt length — reproducible per request whatever the batch
        # composition. keys/prompt_lens only change with slot membership,
        # so they are cached on device (greedy batches trace no RNG at all).
        if self._slot_consts is None:
            self._slot_consts = (
                jnp.stack([s.request._key if s.request else self._zero_key
                           for s in self.slots]),
                jnp.asarray([s.prompt_len for s in self.slots], jnp.int32))
        keys, plens = self._slot_consts
        bt = self._decode_block_tables()

        try:
            self.cache, tok_buf, emit_buf, clens, rem, done = \
                self.progs.decode_chunk(self.params, self.cache, last, clens,
                                        rem, done, temps, top_ks, keys, plens,
                                        bt)
            # the ONE host sync of the chunk: pull tokens + masks + slot state
            tok_buf, emit_buf, clens_h, rem_h, done_h = jax.device_get(
                (tok_buf, emit_buf, clens, rem, done))
        except Exception as e:
            # failure isolation: a dead-lettered decode dispatch (retries
            # exhausted / injected corruption) fails only the slots in this
            # chunk — queued requests and the next step's admissions go on
            self._fail_slots(rest, e)
            return True
        self._breaker_note(True)
        self._decode_syncs += 1
        self._decode_chunks += 1
        dt = time.perf_counter() - t0

        emitted = 0
        for i in rest:
            slot = self.slots[i]
            new = tok_buf[:, i][emit_buf[:, i]]
            slot.generated.extend(int(t) for t in new)
            if slot.drafter is not None and new.size:
                slot.drafter.extend([int(t) for t in new])
            emitted += int(new.size)
            slot.cache_len = int(clens_h[i])
            slot.remaining = int(rem_h[i])
            slot.request.decode_s += dt / max(len(rest), 1)
        self._decode_tokens += emitted
        for i in rest:
            stopped = self._apply_stop(self.slots[i])
            if stopped or bool(done_h[i]):
                self._finalize(i)
        return True

    def run_until_drained(self):
        while self.step() or self._queue:
            pass
