"""Token samplers: greedy / temperature / top-k, pure jax.

``sample`` keeps the original host-friendly API (python-scalar temperature,
branching at trace time). ``sample_batched`` is the serving fast path: all
parameters are traced per-row vectors, so one jit'd callable serves any mix
of greedy and stochastic slots without recompiling — it runs inside the
engine's on-device decode loop. ``accept_batched`` is the speculative-decode
verify step: batched greedy exact-match / rejection-sampling acceptance of
drafted tokens (serving/spec.py proposes them, models verify mode scores
them), distribution-correct for stochastic slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           vocab_limit: int = 0):
    """logits [B, V] -> token ids [B]."""
    if vocab_limit:
        mask = jnp.arange(logits.shape[-1]) < vocab_limit
        logits = jnp.where(mask, logits, NEG)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits >= kth, logits, NEG)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def _top_k_filter(scaled, k):
    """Keep the top-k entries of the trailing axis per row, -inf the rest.

    scaled [..., V]; k broadcastable int32 against the leading axes, with
    ``k <= 0`` meaning no filter for that row and ``k >= V`` degenerating to
    no filter as well (the k-th largest is then the global minimum, so every
    entry passes — see test_serving_fastpath's top-k edge tests).
    """
    V = scaled.shape[-1]
    srt = jnp.sort(scaled, axis=-1)                      # ascending
    idx = jnp.clip(V - k, 0, V - 1)                      # k-th largest
    idx = jnp.broadcast_to(idx[..., None], scaled.shape[:-1] + (1,))
    kth = jnp.take_along_axis(srt, idx, axis=-1)
    keep = (k <= 0)[..., None] | (scaled >= kth)
    return jnp.where(keep, scaled, NEG)


def sample_batched(logits, key, *, temperature, top_k=None, vocab_limit: int = 0):
    """Per-row sampling with traced parameters. logits [B, V] -> ids [B].

    key:         a single PRNG key shared by the batch, or per-row keys
                 [B, 2] — then row b samples with its own key (the serving
                 engine's per-request RNG chains: a request's draws depend
                 only on its own key and token index, never on batch
                 composition — see SamplingParams.seed).
    temperature: [B] f32 (<= 0 means greedy for that row), or None for a
                 statically greedy batch — no RNG / sort ops are traced at
                 all, which matters inside the engine's per-token decode loop.
    top_k:       [B] int32 or None (<= 0 means no top-k filter for that row;
                 k >= vocab also means no filter — never a negative index).
    vocab_limit: static int — ids >= vocab_limit are never produced, and the
                 top-k filter composes (masked ids stay at -inf below any kth
                 threshold, so they are neither kept nor sampled).
    """
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    if vocab_limit:
        vmask = jnp.arange(V) < vocab_limit
        logits = jnp.where(vmask[None, :], logits, NEG)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if temperature is None:
        return greedy
    temperature = jnp.asarray(temperature, jnp.float32).reshape(B)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    if top_k is not None:
        scaled = _top_k_filter(scaled, jnp.asarray(top_k, jnp.int32).reshape(B))
    if key.ndim == 2:                       # per-row (per-request) keys
        stochastic = jax.vmap(
            lambda k, s: jax.random.categorical(k, s))(key, scaled)
        stochastic = stochastic.astype(jnp.int32)
    else:
        stochastic = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, stochastic, greedy)


def accept_batched(logits, inputs, draft_lens, key, *, temperature,
                   top_k=None, vocab_limit: int = 0, use_kernel: bool = False):
    """Batched draft acceptance for drafter-free speculative decoding.

    logits [B, S, V]:  verify-forward logits; ``logits[:, i]`` is the target
                       distribution for the token FOLLOWING input i.
    inputs [B, S]:     the verify-step inputs ``[last, d_1 .. d_k, pad...]``
                       per row, so ``inputs[:, i+1]`` is the draft token that
                       ``logits[:, i]`` is judged against.
    draft_lens [B]:    k per row (0 <= k <= S-1). k == 0 degenerates to a
                       plain decode step: one token sampled from logits[:, 0].
    temperature/top_k/vocab_limit: as in ``sample_batched`` (None temperature
                       = statically greedy batch, no RNG traced).

    Greedy rows accept a draft iff it matches the argmax, so greedy
    speculative output is bit-identical to non-speculative decode. Stochastic
    rows use rejection sampling against the deterministic drafter (q = point
    mass on d): accept d with prob p(d); on reject, sample from the
    renormalized residual (p with d removed). Either way each emitted token
    is marginally distributed exactly as non-speculative sampling — the
    standard speculative-sampling correctness argument specialised to a
    deterministic draft distribution.

    Returns (out_tokens [B, S], out_lens [B]): ``out_tokens[b, :m]`` are the
    accepted drafts, ``out_tokens[b, m]`` the correction (on reject) or bonus
    (full accept) token; ``out_lens = m + 1`` tokens are emitted per row.
    ``use_kernel`` routes the accept-length reduction through the fused
    Pallas scan (kernels/spec_scan.py) on TPU.
    """
    B, S, V = logits.shape
    logits = logits.astype(jnp.float32)
    if vocab_limit:
        vmask = jnp.arange(V) < vocab_limit
        logits = jnp.where(vmask[None, None, :], logits, NEG)
    col = jnp.arange(S, dtype=jnp.int32)[None, :]
    draft_lens = jnp.asarray(draft_lens, jnp.int32).reshape(B)
    # draft token judged at column i (junk at the last column — never read,
    # draft_lens <= S-1 keeps every judged column in range)
    d_next = jnp.concatenate([inputs[:, 1:], inputs[:, :1]], axis=1)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    if temperature is None:
        accept = greedy_tok == d_next
        t_key = c_key = None
    else:
        temperature = jnp.asarray(temperature, jnp.float32).reshape(B)
        scaled = logits / jnp.maximum(temperature, 1e-6)[:, None, None]
        if top_k is not None:
            k = jnp.asarray(top_k, jnp.int32).reshape(B)
            scaled = _top_k_filter(scaled, k[:, None])
        probs = jax.nn.softmax(scaled, axis=-1)
        p_draft = jnp.take_along_axis(probs, d_next[..., None], axis=-1)[..., 0]
        t_key, c_key = jax.random.split(key)
        u = jax.random.uniform(t_key, (B, S))
        accept = jnp.where(temperature[:, None] > 0.0, u < p_draft,
                           greedy_tok == d_next)

    from repro.kernels import spec_scan
    if use_kernel:
        m = spec_scan.accept_len(accept, draft_lens, interpret=False)
    else:
        m = spec_scan.accept_len_ref(accept, draft_lens)

    # correction / bonus token from the target distribution at position m
    l_m = jnp.take_along_axis(logits, m[:, None, None], axis=1)[:, 0]  # [B,V]
    rejected_d = jnp.take_along_axis(d_next, m[:, None], axis=1)[:, 0]
    greedy_m = jnp.argmax(l_m, axis=-1).astype(jnp.int32)
    if temperature is None:
        # greedy reject already implies argmax != d; greedy full-accept takes
        # the free bonus argmax — no residual mass to re-normalize
        t_star = greedy_m
    else:
        scaled_m = l_m / jnp.maximum(temperature, 1e-6)[:, None]
        if top_k is not None:
            scaled_m = _top_k_filter(scaled_m, k)
        # residual for a point-mass drafter: p with the rejected token
        # removed, renormalized (only when a draft was actually rejected)
        drop = (m < draft_lens)[:, None] & \
            (jnp.arange(V)[None, :] == rejected_d[:, None])
        scaled_m = jnp.where(drop, NEG, scaled_m)
        stoch = jax.random.categorical(c_key, scaled_m, axis=-1).astype(jnp.int32)
        t_star = jnp.where(temperature > 0.0, stoch, greedy_m)

    out = jnp.where(col < m[:, None], d_next, 0)
    out = jnp.where(col == m[:, None], t_star[:, None], out)
    return out, m + 1
