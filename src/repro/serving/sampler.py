"""Token samplers: greedy / temperature / top-k, pure jax."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           vocab_limit: int = 0):
    """logits [B, V] -> token ids [B]."""
    if vocab_limit:
        mask = jnp.arange(logits.shape[-1]) < vocab_limit
        logits = jnp.where(mask, logits, -1e30)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits >= kth, logits, -1e30)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
