"""Token samplers: greedy / temperature / top-k, pure jax.

``sample`` keeps the original host-friendly API (python-scalar temperature,
branching at trace time). ``sample_batched`` is the serving fast path: all
parameters are traced per-row vectors, so one jit'd callable serves any mix
of greedy and stochastic slots without recompiling — it runs inside the
engine's on-device decode loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           vocab_limit: int = 0):
    """logits [B, V] -> token ids [B]."""
    if vocab_limit:
        mask = jnp.arange(logits.shape[-1]) < vocab_limit
        logits = jnp.where(mask, logits, -1e30)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits >= kth, logits, -1e30)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_batched(logits, key, *, temperature, top_k=None, vocab_limit: int = 0):
    """Per-row sampling with traced parameters. logits [B, V] -> ids [B].

    temperature: [B] f32 (<= 0 means greedy for that row), or None for a
                 statically greedy batch — no RNG / sort ops are traced at
                 all, which matters inside the engine's per-token decode loop.
    top_k:       [B] int32 or None (<= 0 means no top-k filter for that row).
    vocab_limit: static int — ids >= vocab_limit are never produced.
    """
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    if vocab_limit:
        vmask = jnp.arange(V) < vocab_limit
        logits = jnp.where(vmask[None, :], logits, -1e30)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if temperature is None:
        return greedy
    temperature = jnp.asarray(temperature, jnp.float32).reshape(B)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    if top_k is not None:
        k = jnp.asarray(top_k, jnp.int32).reshape(B)
        srt = jnp.sort(scaled, axis=-1)                      # ascending
        idx = jnp.clip(V - k, 0, V - 1)                      # k-th largest
        kth = jnp.take_along_axis(srt, idx[:, None], axis=-1)
        keep = (k <= 0)[:, None] | (scaled >= kth)
        scaled = jnp.where(keep, scaled, -1e30)
    stochastic = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, stochastic, greedy)
