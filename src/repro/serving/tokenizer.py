"""Byte-level tokenizer with a deterministic pair-merge vocabulary.

Real enough to drive the serving engine end-to-end on CPU: reversible,
vocab-size aware (fits every assigned architecture's vocab), no external
files. ids 0..255 = bytes; 256.. = greedy merges of frequent ASCII pairs;
last ids reserved for specials.
"""
from __future__ import annotations

import itertools
from typing import List

PAD, BOS, EOS = 0x100, 0x101, 0x102  # raw special points remapped per vocab

_COMMON_PAIRS = [
    "e ", " t", "th", "he", "s ", " a", "in", "d ", "er", "an", "re", "on",
    " s", "t ", "or", "en", " c", " o", "es", " p", "ar", "al", " m", "te",
    "st", " i", "ti", "at", "ng", "to", "is", " f", "ed", "it", "ou", " b",
    "ro", "ur", "ll", "ra", "el", "nd", " w", "as", "ion", "ent", "the ",
    "and ", "ing ", "tion", " of ", " in ", " to ",
]


class ByteTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size >= 260, vocab_size
        self.vocab_size = vocab_size
        n_merges = min(len(_COMMON_PAIRS), vocab_size - 256 - 3)
        self.merges = {p: 256 + i for i, p in enumerate(_COMMON_PAIRS[:n_merges])}
        self.pad_id = vocab_size - 3
        self.bos_id = vocab_size - 2
        self.eos_id = vocab_size - 1
        # longest-first matching
        self._ordered = sorted(self.merges, key=len, reverse=True)

    def encode(self, text: str, *, bos: bool = True) -> List[int]:
        ids: List[int] = [self.bos_id] if bos else []
        i = 0
        while i < len(text):
            for p in self._ordered:
                if text.startswith(p, i):
                    ids.append(self.merges[p])
                    i += len(p)
                    break
            else:
                b = text[i].encode("utf-8", errors="replace")
                ids.extend(b if len(b) > 0 else [ord("?")])
                i += 1
        return ids

    def decode(self, ids) -> str:
        inv = {v: k for k, v in self.merges.items()}
        out: List[str] = []
        byte_run: List[int] = []

        def flush():
            if byte_run:
                out.append(bytes(byte_run).decode("utf-8", errors="replace"))
                byte_run.clear()

        for t in ids:
            t = int(t)
            if t in (self.pad_id, self.bos_id, self.eos_id):
                continue
            if t < 256:
                byte_run.append(t)
            elif t in inv:
                flush()
                out.append(inv[t])
            # unknown ids (model samples beyond mapped range): skip
        flush()
        return "".join(out)
