"""Crash-safe session journal: the durable-state half of the fault layer.

A session's retained tail (partial KV tail page / end-of-generation state
snapshot, serving/scheduler.py) is device state and dies with the engine. The
journal keeps the *token-level* description of every session — the exact
conversation token stream plus its text — which is all a fresh ``LLMServer``
needs to rebuild the tail bit-identically: ``restore_sessions()`` replays the
stream through the existing ``enqueue(token_ids=)`` path, re-prefilling
``all_tokens[:-1]`` and re-capturing the tail at the exact end-of-generation
boundary. This is the paper's DynamoDB-memory analogue: conversation state
outlives the process serving it.

The journal is in-memory by default (one small record per session, updated
at each turn's finalize). Give it a ``path`` to spill JSON after every
update; ``SessionJournal.load(path)`` recovers it after a crash:

    old = SessionJournal.load("/tmp/sessions.json")
    server = LLMServer(cfg, journal_path="/tmp/sessions.json")
    sessions = server.restore_sessions(old)     # old sid -> live Session
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Dict, List, Optional

__all__ = ["JournalEntry", "SessionJournal"]


@dataclasses.dataclass
class JournalEntry:
    """One session's replayable state as of its last finished turn.

    ``all_tokens`` is the exact (truncation-adjusted) conversation token
    stream — prompt + generated, stop-trimmed; its first ``len - 1`` tokens
    are the processed prefix, the final token the sampled-but-unconsumed
    continuation. ``text`` is the matching conversation text the next
    turn's prompt must extend.
    """
    sid: int
    text: str
    all_tokens: List[int]
    turns: int


class SessionJournal:
    """Latest-state-per-session journal with optional JSON spill.

    Records are idempotent per sid (each turn's finalize overwrites the
    session's entry); ``drop`` removes a closed session. Spill writes are
    atomic (temp file + rename) so a crash mid-spill leaves the previous
    consistent journal on disk. All mutation and the spill run under one
    re-entrant lock: the pump thread finalizes turns while caller threads
    close sessions / dump, and two concurrent atomic renames of the same
    temp file would otherwise race.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._entries: Dict[int, JournalEntry] = {}
        self._lock = threading.RLock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def record(self, sid: int, text: str, all_tokens: List[int], turns: int):
        with self._lock:
            self._entries[sid] = JournalEntry(sid, text, list(all_tokens),
                                              turns)
            if self.path:
                self._spill()

    def drop(self, sid: int):
        with self._lock:
            if self._entries.pop(sid, None) is not None and self.path:
                self._spill()

    def get(self, sid: int) -> Optional[JournalEntry]:
        with self._lock:
            return self._entries.get(sid)

    def entries(self) -> List[JournalEntry]:
        """Stable snapshot (by sid) — safe to iterate while restoring into
        a journal-keeping server."""
        with self._lock:
            return [self._entries[k] for k in sorted(self._entries)]

    # ---- persistence -------------------------------------------------------
    def _spill(self):
        self.dump(self.path)

    def dump(self, path: str):
        with self._lock:
            tmp = path + ".tmp"
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            with open(tmp, "w") as f:
                json.dump([dataclasses.asdict(e) for e in self.entries()], f)
            os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "SessionJournal":
        j = cls()
        with open(path) as f:
            for rec in json.load(f):
                j._entries[rec["sid"]] = JournalEntry(
                    rec["sid"], rec["text"], list(rec["all_tokens"]),
                    rec["turns"])
        return j
