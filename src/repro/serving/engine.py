"""Serving engine: slot-based continuous batching over a sync-free fast path.

The engine owns a fixed decode batch of ``num_slots`` sequences sharing one
ring KV cache (per-slot cache rows). Requests queue up; free slots are
prefilled and join the in-flight decode batch; finished slots are released to
the next request — continuous batching, the vLLM/MaxText serving idiom.

Fast-path structure (see benchmarks/serving_bench.py for the measurements):

* **Bucketed prefill** — prompts are right-padded to a small set of length
  buckets, so the prefill function compiles once per bucket instead of once
  per distinct prompt length. The per-slot cache splice happens *inside* the
  jit (``dynamic_update_slice`` at the slot index, donated shared cache), not
  as a host-side tree-map copy.
* **Chunked decode** — a jit'd ``lax.while_loop`` decodes up to
  ``decode_chunk`` tokens per engine step with a per-slot done mask
  (EOS / token budget / capacity), sampling on device with per-slot
  temperature / top-k (``sampler.sample_batched``). The host syncs at most
  once per chunk, not once per token.
* **Aligned cache** — cache capacity is rounded up to the decode-attention
  kernel block (``block_w``), so the Pallas kernel never re-pads the cache.
* **Chunked prefill** — prompts longer than the largest bucket are split into
  bucket-sized chunks: the first chunk takes the normal bucketed prefill, the
  rest run ``model.extend`` (prefill continuation against the already-filled
  cache). No more silent exact-length fallback past the last bucket; prompts
  truncate only at the hard capacity window, and that truncation is counted
  (``Request.truncated_tokens``, ``stats()["truncated_tokens"]``).
* **Drafter-free speculative decoding** — ``EngineConfig(spec_len=N)``: a
  per-slot n-gram lookup over the request's own context (serving/spec.py —
  no draft model, pure host-side hashing) proposes up to N continuation
  tokens per engine step; ONE jit'd verify forward (``model.verify``) scores
  every draft position for every slot at once and ``sampler.accept_batched``
  commits the accepted prefix plus a correction/bonus token on device.
  Greedy slots accept by exact match (output bit-identical to
  non-speculative decode); temperature slots use rejection-sampling
  acceptance (marginals provably match non-speculative sampling). FAME's
  copy-heavy outputs (tool results / log lines re-surfaced in answers)
  accept most drafts, cutting forwards-per-token several-fold
  (benchmarks/spec_bench.py). EVERY arch takes the batched path: linear
  full-attention caches roll back for free (rejected K/V is position-masked
  until overwritten — dense rows or paged block tables); recurrent / conv /
  mLSTM / sLSTM / ring-KV blocks stage per-position states during the
  verify forward and ``model.verify_commit`` gathers the state at each
  row's accepted length inside the same jit (accept-length state rewind —
  no per-slot replay forward). Slots whose acceptance rate drops below
  ``spec_min_accept`` stop drafting; steps with no drafts anywhere fall
  back to the chunked decode loop.
* **Paged KV + radix prefix sharing** — ``EngineConfig(cache_mode="paged")``
  swaps the dense per-slot cache rows for one pool of fixed-size KV pages
  (serving/kvpool.py) with per-request block tables, indexed by a radix
  token-trie (serving/radix.py). A request whose prompt shares a prefix with
  any earlier request reuses the prefix's pages outright and only prefills
  the suffix — prefill work and cache memory scale with *unique* tokens
  across the batch, the property that makes N agents × one shared system
  prompt sublinear (FAME's context-reuse result, PAPER.md §3.3). Decode
  gathers K/V through the block table (``kernels/paged_decode_attention`` on
  TPU, gather reference on CPU). ``cache_mode="dense"`` keeps the PR-1 path
  for A/B (benchmarks/prefix_bench.py measures both). Admission is
  radix-aware: queued requests sharing the just-admitted prompt's first
  radix block move (stably) to the queue front so one engine step admits
  the whole group while the shared pages are pinned and hot
  (``stats()["grouped_admissions"]``).
* **Per-prefix recurrent-state snapshots** — ``cache_mode="paged"`` on a
  *stateful* arch (recurrent / conv / mLSTM / sLSTM / ring-KV; no shareable
  pages, but O(1) decode state) keeps the dense per-slot cache rows and
  shares prefixes through the same radix trie with a pooled snapshot arena
  instead: after prefilling up to a radix-block boundary the engine splices
  the slot's complete fixed-size state (recurrent h, conv window,
  mLSTM/sLSTM state, ring KV + implicit write cursor) into one arena row
  and hands it to the trie node. A later request that radix-matches the
  prefix restores the nearest boundary snapshot into its slot and prefills
  only the suffix — the exact prefix-reuse the paged path gives attention
  archs, at O(1) storage per boundary (``stats()["snapshot_hits"]`` etc.;
  benchmarks/prefix_bench.py measures it with ``--arch recurrentgemma-9b``).

On CPU it runs reduced configs end-to-end (agents in examples/serve_agents.py
talk to it); on the production mesh the same functions lower through
launch/dryrun.py (prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.serving import kvpool
from repro.serving.radix import RadixTree
from repro.serving.sampler import accept_batched, sample_batched
from repro.serving.spec import NgramDrafter
from repro.serving.tokenizer import ByteTokenizer


def _slot_extract(cache, slot):
    """Single-row view of slot ``slot``: scan leaves are [L, B, ...], tail
    leaves [B, ...] (mirrors ``_slot_splice``)."""
    def _scan_get(full):
        return jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=1)

    def _tail_get(full):
        return jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=0)

    return {k: jax.tree.map(_scan_get if k == "scan" else _tail_get, cache[k])
            for k in cache}


def _slot_splice(cache, cache1, slot):
    """Write a single-row cache pytree back into row ``slot``."""
    def _scan_leaf(full, one):
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype),
            (jnp.int32(0), slot) + (jnp.int32(0),) * (full.ndim - 2))

    def _tail_leaf(full, one):
        return jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype),
            (slot,) + (jnp.int32(0),) * (full.ndim - 1))

    return {k: jax.tree.map(_scan_leaf if k == "scan" else _tail_leaf,
                            cache[k], cache1[k])
            for k in cache}


def _select_rows(new_cache, old_cache, keep):
    """Per-row cache select: rows with ``keep`` take the new cache, the rest
    keep the old one bit-exactly. Scan leaves are [L, B, ...], tail leaves
    [B, ...] (the _slot_extract convention)."""
    def _scan_sel(n, o):
        return jnp.where(keep.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o)

    def _tail_sel(n, o):
        return jnp.where(keep.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)

    return {k: jax.tree.map(_scan_sel if k == "scan" else _tail_sel,
                            new_cache[k], old_cache[k])
            for k in new_cache}


def _auto_buckets(capacity: int, lo: int = 32) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to (and including) capacity."""
    buckets = []
    b = min(lo, capacity)
    while b < capacity:
        buckets.append(b)
        b *= 2
    buckets.append(capacity)
    return tuple(buckets)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving fast-path knobs.

    prefill_buckets: explicit bucket lengths; None → auto powers-of-two;
                     empty tuple → exact-length prefill (one compile per
                     distinct prompt length — the pre-fast-path behaviour,
                     kept for A/B benchmarking).
    decode_chunk:    decode tokens per jit'd inner loop (1 → one host sync
                     per token, the pre-fast-path behaviour). All-greedy
                     batches additionally compile a sampler-free loop body
                     (no per-step RNG / top-k sort).
    block_w:         decode-attention KV block; cache capacity is rounded up
                     to a multiple of it so the kernel never re-pads.
    donate:          donate the shared cache to prefill/decode jits
                     (None → auto: on everywhere except CPU, where XLA
                     ignores donation and warns).
    cache_mode:      "dense" (PR-1 per-slot cache rows) or "paged" (radix
                     prefix sharing). On full-attention archs "paged" means
                     one KV page pool + per-request block tables
                     (kvpool.supports_paged); on stateful archs (recurrent /
                     conv / xLSTM / ring-KV — kvpool.supports_snapshots) it
                     keeps dense rows and shares prefixes through per-prefix
                     recurrent-state snapshots instead.
    page_size:       KV tokens per page in paged mode; capacity is rounded up
                     to a multiple of it. Smaller pages share finer prefixes
                     at more gather overhead. Snapshot mode reuses it as the
                     radix block granularity.
    num_pages:       device pages in the pool (None → auto: trash page +
                     2 × num_slots × pages-per-request, leaving headroom for
                     retained prefixes before LRU eviction kicks in).
    num_snapshots:   snapshot-arena rows in snapshot mode (None → auto:
                     ~num_slots × boundaries-per-request + headroom). Each
                     row holds one complete per-sequence state, so memory is
                     num_snapshots × state-size — size it to taste and let
                     LRU eviction manage the rest.
    snap_stride:     radix blocks between snapshot boundaries (1 = capture at
                     every block, the finest prefix reuse; larger strides
                     trade hit depth for fewer arena rows and fewer prefill
                     chunk splits).
    spec_len:        max draft tokens per speculative verify step (0 = off).
                     A per-slot n-gram lookup drafter (serving/spec.py, no
                     draft model) proposes continuations; one verify forward
                     scores every draft position at once and an accept/
                     rollback step commits the matched prefix. Greedy slots
                     accept by exact match (outputs bit-identical to
                     non-speculative decode); temperature slots use
                     rejection-sampling acceptance (distribution-correct).
    spec_ngram_min/max: suffix n-gram lengths the drafter indexes.
    spec_min_accept: per-slot drafting turns off for the rest of a request
                     once its acceptance rate drops below this (after
                     spec_warmup drafted tokens) — unpredictable outputs
                     then pay zero verify overhead.
    spec_warmup:     drafted tokens per slot before adaptive disable engages.
    """
    prefill_buckets: Optional[Tuple[int, ...]] = None
    decode_chunk: int = 16
    block_w: int = 256
    donate: Optional[bool] = None
    cache_mode: str = "dense"
    page_size: int = 16
    num_pages: Optional[int] = None
    num_snapshots: Optional[int] = None
    snap_stride: int = 1
    spec_len: int = 0
    spec_ngram_min: int = 2
    spec_ngram_max: int = 4
    spec_min_accept: float = 0.35
    spec_warmup: int = 64


@dataclasses.dataclass
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: int = 0
    # filled by the engine
    prompt_tokens: int = 0
    truncated_tokens: int = 0      # dropped at the hard capacity window
    prefix_hit_tokens: int = 0     # paged: prompt tokens served from shared pages
    output_text: str = ""
    output_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    latency_s: float = 0.0
    admit_index: int = -1
    _submit_t: float = 0.0
    _ids: Optional[list] = None    # tokenized prompt, cached across admission
                                   # retries (paged head-of-line waits)
    _grouped: bool = False         # moved up the queue by radix-aware
                                   # admission batching (paged mode)


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    cache_len: int = 0
    remaining: int = 0
    generated: Optional[list] = None
    # paged mode bookkeeping
    token_ids: Optional[list] = None      # prompt ids (post-truncation)
    pages_shared: Optional[list] = None   # radix-matched prefix pages (tree-owned)
    pages_priv: Optional[list] = None     # this request's own pages
    node: Optional[object] = None         # pinned radix node
    # speculative decoding bookkeeping
    drafter: Optional[NgramDrafter] = None
    spec_on: bool = False                 # adaptive per-slot enable
    spec_drafted: int = 0                 # draft tokens proposed for this slot
    spec_accepted: int = 0                # ... of which verify accepted


class ServingEngine:
    def __init__(self, cfg, *, num_slots: int = 4, capacity: int = 512,
                 params=None, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None):
        self.engine_cfg = engine_cfg or EngineConfig()
        if self.engine_cfg.decode_chunk < 1:
            raise ValueError(
                f"decode_chunk must be >= 1, got {self.engine_cfg.decode_chunk} "
                "(a zero-length chunk makes no progress)")
        mode = self.engine_cfg.cache_mode
        if mode not in ("dense", "paged"):
            raise ValueError(f"cache_mode must be 'dense' or 'paged', got {mode!r}")
        # "paged" resolves per arch family: KV page pool for full-attention
        # archs, per-prefix recurrent-state snapshots for stateful archs
        self.paged = self.snapshots = False
        if mode == "paged":
            ok, why = kvpool.supports_paged(cfg)
            if ok:
                self.paged = True
            else:
                ok2, why2 = kvpool.supports_snapshots(cfg)
                if not ok2:
                    raise ValueError(
                        f"cache_mode='paged' unsupported for {cfg.name}: "
                        f"{why}; {why2}")
                self.snapshots = True
        if self.engine_cfg.spec_len < 0:
            raise ValueError(
                f"spec_len must be >= 0, got {self.engine_cfg.spec_len}")
        self.spec = self.engine_cfg.spec_len > 0
        if self.spec and cfg.modality != "text":
            raise ValueError(
                "speculative decoding needs token-id inputs; "
                f"modality={cfg.modality!r} has no n-gram stream to draft "
                "from")
        # pure full-attention caches tolerate done-row decode writes (same
        # position, same value — idempotent); every other cache family keeps
        # real state that must be frozen for rows sitting a chunk out
        self._freeze_done_rows = not kvpool.supports_paged(cfg)[0]
        bw = max(1, self.engine_cfg.block_w)
        if capacity > bw:
            capacity = -(-capacity // bw) * bw      # align to kernel block
        ps = self.engine_cfg.page_size
        if self.paged or self.snapshots:
            if ps < 1:
                raise ValueError(f"page_size must be >= 1, got {ps}")
        if self.paged:
            capacity = -(-capacity // ps) * ps      # align to page size
        self.cfg = dataclasses.replace(cfg, decode_block_w=bw)
        self.model = Model(self.cfg)
        self.tokenizer = ByteTokenizer(cfg.vocab_size)
        self.num_slots = num_slots
        self.capacity = capacity
        buckets = self.engine_cfg.prefill_buckets
        self.buckets: Tuple[int, ...] = (_auto_buckets(capacity)
                                         if buckets is None else
                                         tuple(sorted(buckets)))
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        if self.paged:
            self._bt_width = capacity // ps
            n_pages = self.engine_cfg.num_pages
            if n_pages is None:
                n_pages = 1 + 2 * num_slots * self._bt_width
            # self.cache IS the page pool in paged mode: same pytree
            # structure, batch axis re-purposed as the page axis
            self.cache = kvpool.init_paged_cache(self.cfg, n_pages, ps)
            self.kvpool = kvpool.PagePool(n_pages)
            self.radix = RadixTree(ps)
            self._bt_device = None      # cached decode block table (device)
        else:
            self.cache = self.model.init_cache(num_slots, capacity)
            self.kvpool = None
            self.radix = None
        if self.snapshots:
            # snapshot mode: dense per-slot rows + a radix trie whose nodes
            # own rows of a pooled snapshot arena (the model's cache pytree
            # with batch axis = snapshot slots)
            self.radix = RadixTree(ps)
            stride = max(1, self.engine_cfg.snap_stride)
            n_snaps = self.engine_cfg.num_snapshots
            if n_snaps is None:
                n_snaps = 1 + num_slots * (-(-capacity // (ps * stride)) + 2)
            self.snaps = kvpool.SnapshotArena(n_snaps)
            self.snap_arena = self.model.init_cache(n_snaps, capacity)
        else:
            self.snaps = None
            self.snap_arena = None
        self.slots = [_Slot() for _ in range(num_slots)]
        self._queue: "collections.deque[Request]" = collections.deque()
        self._rng = jax.random.PRNGKey(seed + 1)
        self._next_rid = 0
        self._next_admit = 0

        # perf counters (benchmarks/{serving,prefix}_bench.py read these)
        self._prefill_shapes: set = set()        # 1 jit compile per entry
        self._extend_shapes: set = set()         # ... for extend chunks
        self._decode_syncs = 0                   # blocking pulls in decode
        self._prefill_syncs = 0                  # blocking pulls at admission
        self._decode_tokens = 0
        self._decode_chunks = 0
        self._extend_chunks = 0
        self._truncated_tokens = 0               # dropped at capacity window
        self._truncated_requests = 0
        self._pad_tokens = 0                     # prefill bucket padding waste
        self._prompt_tokens = 0                  # real (unpadded) prompt tokens
        self._prefix_hit_tokens = 0              # paged: served from shared pages
        self._draft_tokens = 0                   # spec: tokens proposed
        self._accepted_tokens = 0                # spec: drafts verify accepted
        self._verify_steps = 0                   # spec: verify forwards run
        self._grouped_admissions = 0             # paged/snap: radix-grouped
        self._snap_hits = 0                      # snap: admissions restored
        self._snap_misses = 0                    # ... or prefilled from zero
        self._snap_captures = 0                  # snapshots spliced to arena

        donate = self.engine_cfg.donate
        if donate is None:
            donate = jax.default_backend() != "cpu"
        dargs = (1,) if donate else ()
        self._jit_prefill = jax.jit(self._prefill_fn, donate_argnums=dargs)
        self._jit_decode_chunk = jax.jit(self._decode_chunk_fn,
                                         donate_argnums=dargs)
        self._jit_extend = jax.jit(self._extend_fn, donate_argnums=dargs,
                                   static_argnames=("sample",))
        self._jit_extend_paged = jax.jit(self._extend_paged_fn,
                                         donate_argnums=dargs,
                                         static_argnames=("sample",))
        if self.snapshots:
            d0 = (0,) if donate else ()
            self._jit_snap_capture = jax.jit(self._snap_capture_fn,
                                             donate_argnums=d0)
            self._jit_snap_restore = jax.jit(self._snap_restore_fn,
                                             donate_argnums=d0)
        if self.spec:
            # ONE jit per verify step for every arch: forward + accept +
            # accept-length state rewind (model.verify_commit) fused
            self._jit_verify = jax.jit(self._verify_fn, donate_argnums=dargs)

    # ---- jit'd computations ------------------------------------------------
    def _prefill_fn(self, params, cache, tokens, positions, slot, length, key,
                    temperature, top_k):
        """Prefill one (padded) prompt and splice it into the shared cache.

        Everything — forward pass, per-slot cache splice, first-token sample —
        happens in one jit, compiled once per bucket length.
        """
        cache1 = self.model.init_cache(1, self.capacity)
        batch = {("frames" if self.cfg.modality == "audio_frames" else "tokens"): tokens,
                 "positions": positions}
        logits, cache1 = self.model.prefill(params, batch, cache1,
                                            length=length, with_logits="last")
        tok = self._sample_last(logits, length, key, temperature, top_k)
        # splice the single-row cache into slot `slot` of the shared cache;
        # scan caches are [L, B, ...] (batch dim 1), tail caches [B, ...]
        return _slot_splice(cache, cache1, slot), tok

    def _sample_last(self, logits, length, key, temperature, top_k):
        """Sample one token from the logits at position ``length - 1``
        (or from already-sliced ``with_logits="last"`` logits [B, 1, V])."""
        if logits.shape[1] == 1:
            last = logits[:, 0]                                      # [1, V]
        else:
            last = jax.lax.dynamic_index_in_dim(logits, length - 1, axis=1,
                                                keepdims=False)      # [1, V]
        tok = sample_batched(last, key, temperature=temperature[None],
                             top_k=top_k[None], vocab_limit=self.cfg.vocab_size)
        return tok[0]

    def _extend_fn(self, params, cache, tokens, positions, slot, start,
                   length, key, temperature, top_k, *, sample: bool):
        """Dense chunked-prefill continuation for one slot.

        Extract the slot's cache row, run ``model.extend`` (the chunk attends
        to the already-prefilled prefix + itself; recurrent state resumes),
        splice the row back — all in one jit, compiled once per chunk shape.
        ``sample=True`` (the prompt's final chunk) additionally unembeds and
        samples at the last valid position; intermediate chunks skip the
        unembed matmul entirely.
        """
        cache1 = _slot_extract(cache, slot)
        tok_key = ("frames" if self.cfg.modality == "audio_frames" else "tokens")
        batch = {tok_key: tokens, "positions": positions}
        logits, cache1 = self.model.extend(
            params, batch, cache1, start, length=length,
            with_logits="last" if sample else False)
        tok = (self._sample_last(logits, length, key, temperature, top_k)
               if sample else jnp.int32(-1))
        return _slot_splice(cache, cache1, slot), tok

    def _extend_paged_fn(self, params, pool, tokens, positions, bt, start,
                         length, key, temperature, top_k, *, sample: bool):
        """Paged prefill: write the chunk's K/V into this request's pages and
        attend to the full block-table view (shared prefix pages included —
        the radix-matched prefix is never recomputed)."""
        tok_key = ("frames" if self.cfg.modality == "audio_frames" else "tokens")
        batch = {tok_key: tokens, "positions": positions}
        logits, pool = self.model.extend(
            params, batch, pool, start, length=length, block_tables=bt,
            with_logits="last" if sample else False)
        tok = (self._sample_last(logits, length, key, temperature, top_k)
               if sample else jnp.int32(-1))
        return pool, tok

    def _decode_chunk_fn(self, params, cache, last_tok, cache_lens, remaining,
                         done, temps, top_ks, key, block_tables=None):
        """Decode up to ``decode_chunk`` tokens for every live slot on device.

        Per-slot done mask (EOS / budget / capacity); finished or empty slots
        keep running in the fixed batch but stop emitting and stop advancing
        their cache row. Returns everything the host needs in one pull.
        """
        chunk = self.engine_cfg.decode_chunk
        B = self.num_slots
        eos = self.tokenizer.eos_id
        tok_buf = jnp.zeros((chunk, B), jnp.int32)
        emit_buf = jnp.zeros((chunk, B), bool)

        def cond(st):
            i = st[0]
            return (i < chunk) & jnp.any(~st[5])

        def body(st):
            i, cache, last, clens, rem, done, key, tb, eb = st
            if self.cfg.modality == "audio_frames":
                # same frame-embedding stub the admission path applies
                toks = jax.nn.one_hot(last[:, None] % self.cfg.d_model,
                                      self.cfg.d_model,
                                      dtype=jnp.dtype(self.cfg.dtype))
                batch = {"frames": toks, "positions": clens[:, None]}
            else:
                batch = {"tokens": last[:, None], "positions": clens[:, None]}
            logits, new_cache = self.model.decode_step(params, batch, cache,
                                                       clens,
                                                       block_tables=block_tables)
            if self._freeze_done_rows:
                # stateful archs: a done-masked row must not keep advancing
                # its recurrent / conv / mLSTM / sLSTM state on a stale
                # input — above all a spec-handled slot sitting this chunk
                # out, which continues decoding next step. Full-attention
                # rows skip this (their stale write is position-masked and
                # idempotent; their caches are also the big ones).
                cache = _select_rows(new_cache, cache, ~done)
            else:
                cache = new_cache
            if temps is None:                   # statically greedy batch:
                sub = key                       # no RNG / sort in the loop
            else:
                key, sub = jax.random.split(key)
            nxt = sample_batched(logits[:, 0], sub, temperature=temps,
                                 top_k=top_ks, vocab_limit=self.cfg.vocab_size)
            emit = ~done
            last = jnp.where(emit, nxt, last)
            clens = clens + emit.astype(jnp.int32)
            rem = rem - emit.astype(jnp.int32)
            done = done | (emit & ((rem <= 0) | (nxt == eos)
                                   | (clens >= self.capacity - 1)))
            tb = tb.at[i].set(jnp.where(emit, nxt, 0))
            eb = eb.at[i].set(emit)
            return (i + 1, cache, last, clens, rem, done, key, tb, eb)

        st = (jnp.int32(0), cache, last_tok, cache_lens, remaining, done,
              key, tok_buf, emit_buf)
        _, cache, last_tok, cache_lens, remaining, done, _, tok_buf, emit_buf = \
            jax.lax.while_loop(cond, body, st)
        return cache, tok_buf, emit_buf, cache_lens, remaining, done

    # ---- speculative decode (drafter-free): jit'd verify + accept + rewind -
    def _verify_fn(self, params, cache, tokens, clens, lens, temps, top_ks,
                   key, block_tables=None):
        """One batched speculative verify step for every slot — any arch.

        tokens [B, S]: ``[last, d_1 .. d_k, pad]`` per row (S = spec_len+1),
        lens [B] = k+1 valid inputs (0 for rows sitting this verify out —
        empty, done, or undrafted slots: no writes, no commits; undrafted
        slots take the chunked decode loop this step instead). One forward
        scores all draft positions (staging per-position states for stateful
        blocks); accept_batched picks the matched prefix + a correction/
        bonus token per drafted row; ``model.verify_commit`` then rewinds
        every stateful block to its row's accepted length with gathers /
        ring splices — all inside this one jit, no per-slot replay.
        """
        positions = clens[:, None] + jnp.arange(tokens.shape[1],
                                                dtype=jnp.int32)[None, :]
        batch = {"tokens": tokens, "positions": positions}
        logits, staged = self.model.verify(params, batch, cache, clens,
                                           lens=lens,
                                           block_tables=block_tables)
        out_tok, out_len = accept_batched(
            logits, tokens, jnp.maximum(lens - 1, 0), key,
            temperature=temps, top_k=top_ks,
            vocab_limit=self.cfg.vocab_size, use_kernel=self.cfg.use_pallas)
        cache = self.model.verify_commit(staged, clens, out_len, lens)
        return cache, out_tok, out_len

    # ---- per-prefix snapshot splices (snapshot mode) -----------------------
    def _snap_capture_fn(self, arena, cache, sid, slot):
        """Copy slot ``slot``'s complete state row into arena row ``sid``."""
        return _slot_splice(arena, _slot_extract(cache, slot), sid)

    def _snap_restore_fn(self, cache, arena, sid, slot):
        """Restore arena row ``sid`` into slot ``slot`` — equivalent to
        having prefilled the snapshot's prefix into that slot."""
        return _slot_splice(cache, _slot_extract(arena, sid), slot)

    # ---- public API -----------------------------------------------------------
    def submit(self, prompt: str, *, max_new_tokens: int = 64,
               temperature: float = 0.0, top_k: int = 0) -> Request:
        if max_new_tokens >= self.capacity - 1:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no room for the "
                f"prompt in a capacity-{self.capacity} cache "
                f"(need max_new_tokens <= capacity - 2)")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        self._next_rid += 1
        req = Request(self._next_rid, prompt, max_new_tokens, temperature,
                      top_k)
        req._submit_t = time.perf_counter()
        self._queue.append(req)
        return req

    def generate(self, prompt: str, *, max_new_tokens: int = 64,
                 temperature: float = 0.0, top_k: int = 0) -> str:
        req = self.submit(prompt, max_new_tokens=max_new_tokens,
                          temperature=temperature, top_k=top_k)
        self.run_until_drained()
        return req.output_text

    def stats(self) -> dict:
        toks = max(self._decode_tokens, 1)
        out = {
            "cache_mode": self.engine_cfg.cache_mode,
            "prefill_compiles": len(self._prefill_shapes),
            "extend_compiles": len(self._extend_shapes),
            "prefill_buckets": list(self.buckets),
            "decode_chunk": self.engine_cfg.decode_chunk,
            "decode_tokens": self._decode_tokens,
            "decode_chunks": self._decode_chunks,
            "extend_chunks": self._extend_chunks,
            "host_syncs": self._decode_syncs,
            "host_syncs_per_token": self._decode_syncs / toks,
            # admission also pulls the first sampled token (once per request,
            # not per token) — reported separately so the decode-path sync
            # rate above stays honest
            "prefill_syncs": self._prefill_syncs,
            # prompt accounting: hard-window truncation (the seed engine
            # dropped these silently) and bucket padding waste (compute spent
            # on pad rows — the knob for tuning prefill_buckets from bench
            # JSON)
            "truncated_requests": self._truncated_requests,
            "truncated_tokens": self._truncated_tokens,
            "prompt_tokens": self._prompt_tokens,
            "prefill_pad_tokens": self._pad_tokens,
            "prefill_pad_frac": self._pad_tokens /
                max(self._pad_tokens + self._prompt_tokens
                    - self._prefix_hit_tokens, 1),
            # speculative decode (all zero when spec_len == 0): drafted vs
            # verify-accepted tokens, and how many verify forwards ran —
            # acceptance_rate is the knob for tuning spec_len / the n-gram
            # range from bench JSON (benchmarks/spec_bench.py)
            "spec_len": self.engine_cfg.spec_len,
            "draft_tokens": self._draft_tokens,
            "accepted_tokens": self._accepted_tokens,
            "acceptance_rate": self._accepted_tokens /
                max(self._draft_tokens, 1),
            "verify_steps": self._verify_steps,
        }
        if self.paged or self.snapshots:
            out.update({
                "page_size": self.engine_cfg.page_size,
                "radix_nodes": self.radix.num_nodes,
                # the headline: prompt tokens served straight from shared
                # pages / restored state snapshots instead of re-prefilled
                "prefix_hit_tokens": self._prefix_hit_tokens,
                "prefix_hit_rate": self._prefix_hit_tokens /
                    max(self._prompt_tokens, 1),
                # queued requests admitted in the same engine step as an
                # earlier request sharing their first radix block (the
                # shared pages/snapshots are matched while still pinned/hot)
                "grouped_admissions": self._grouped_admissions,
            })
        if self.paged:
            out.update({
                "pages_total": self.kvpool.num_pages,
                "pages_free": self.kvpool.num_free,
                "pages_peak_in_use": self.kvpool.peak_in_use,
                "radix_evicted_pages": self.radix.evicted_pages,
            })
        if self.snapshots:
            out.update({
                # per-prefix recurrent-state snapshot arena: hits restore a
                # boundary state instead of re-prefilling; misses prefill
                # from scratch; evictions are LRU trie leaves reclaimed when
                # the arena fills (tune num_snapshots / snap_stride from
                # these)
                "snapshots_total": self.snaps.num_snaps,
                "snapshots_free": self.snaps.num_free,
                "snapshots_peak_in_use": self.snaps.peak_in_use,
                "snapshot_hits": self._snap_hits,
                "snapshot_misses": self._snap_misses,
                "snapshot_captures": self._snap_captures,
                "snapshot_evictions": self.radix.evicted_snaps,
            })
        return out

    # ---- engine loop --------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n                        # exact-length (legacy) mode

    def _chunk_plan(self, n: int, start: int) -> List[Tuple[int, int, int]]:
        """Split ``n`` prompt tokens beginning at position ``start`` into
        prefill chunks: (offset, real_len, padded_len) triples. All chunks
        but the last are exactly the largest bucket; the last is bucketed
        (and clamped so the padded write never overruns capacity)."""
        mb = max(self.buckets) if self.buckets else n
        plan = []
        off = 0
        while off < n:
            rest = n - off
            if rest > mb:
                plan.append((off, mb, mb))
            else:
                padded = min(self._bucket_for(rest),
                             self.capacity - (start + off))
                plan.append((off, rest, padded))
            off += plan[-1][1]
        return plan

    def _chunk_batch(self, ids: List[int], start: int, padded: int):
        """Device token/position arrays for one right-padded prefill chunk."""
        padded_ids = ids + [self.tokenizer.pad_id] * (padded - len(ids))
        tokens = jnp.asarray([padded_ids], jnp.int32)
        positions = start + jnp.arange(padded, dtype=jnp.int32)[None, :]
        if self.cfg.modality == "audio_frames":
            # modality stub: frame embeddings stand in for token ids
            tokens = jax.nn.one_hot(tokens % self.cfg.d_model, self.cfg.d_model,
                                    dtype=jnp.dtype(self.cfg.dtype))
        return tokens, positions

    def _encode_prompt(self, req: Request) -> List[int]:
        """Tokenize + clamp to the capacity window, counting what was cut
        (the seed engine dropped tokens here with no trace at all)."""
        window = self.capacity - req.max_new_tokens - 1   # >= 1 (submit guard)
        if req._ids is None:
            req._ids = self.tokenizer.encode(req.prompt)
        full = req._ids
        ids = full[-window:]
        req.truncated_tokens = len(full) - len(ids)
        if req.truncated_tokens:
            self._truncated_tokens += req.truncated_tokens
            self._truncated_requests += 1
        req.prompt_tokens = len(ids)
        self._prompt_tokens += len(ids)
        return ids

    def _prefill_span(self, si: int, req: Request, ids: List[int],
                      start: int, end: int, *, sample: bool):
        """Prefill ``ids[start:end]`` into slot ``si`` in bucketed chunks.

        ``start == 0`` opens with the PR-1 bucketed prefill (fresh cache
        row — it always unembeds one position and samples; a non-final span
        discards that token); every other chunk is an ``extend``
        continuation against the already-filled row (restored snapshot
        included) that unembeds + samples only when it is the last chunk
        and ``sample``. Returns the last chunk's sampled token.
        """
        plan = self._chunk_plan(end - start, start)
        tok = None
        for ci, (off, real, padded) in enumerate(plan):
            o = start + off
            tokens, positions = self._chunk_batch(ids[o:o + real], o, padded)
            self._rng, k = jax.random.split(self._rng)
            self._pad_tokens += padded - real
            last = ci == len(plan) - 1
            if o == 0:
                self._prefill_shapes.add((padded, self.cfg.modality))
                self.cache, t = self._jit_prefill(
                    self.params, self.cache, tokens, positions,
                    jnp.int32(si), jnp.int32(real), k,
                    jnp.float32(req.temperature), jnp.int32(req.top_k))
            else:
                self._extend_shapes.add((padded, self.cfg.modality))
                self._extend_chunks += 1
                self.cache, t = self._jit_extend(
                    self.params, self.cache, tokens, positions,
                    jnp.int32(si), jnp.int32(o), jnp.int32(real), k,
                    jnp.float32(req.temperature), jnp.int32(req.top_k),
                    sample=sample and last)
            if last:
                tok = t
        return tok

    def _admit_dense(self, si: int, slot: _Slot, req: Request):
        ids = self._encode_prompt(req)
        first = self._prefill_span(si, req, ids, 0, len(ids), sample=True)
        slot.request = req
        slot.cache_len = len(ids)
        slot.remaining = req.max_new_tokens - 1
        slot.generated = [int(first)]                     # one host sync
        self._arm_spec(slot, ids)
        self._prefill_syncs += 1
        return True

    def _admit_paged(self, si: int, slot: _Slot, req: Request):
        """Paged admission: radix-match the prompt, reserve pages, prefill
        only the un-matched suffix. Returns False (request stays queued) when
        the pool can't supply pages even after LRU eviction."""
        ids = self._encode_prompt(req)
        ps = self.engine_cfg.page_size
        # always recompute at least the last prompt token (its logits seed
        # the first sampled token), so cap the usable match one token short
        shared, node = self.radix.match(ids[:len(ids) - 1])
        prefix_len = len(shared) * ps
        total_pages = -(-min(len(ids) + req.max_new_tokens + 1,
                             self.capacity) // ps)
        priv = self.kvpool.alloc(total_pages - len(shared))
        if priv is None:
            freed = self.radix.evict(total_pages - len(shared)
                                     - self.kvpool.num_free)
            self.kvpool.free(freed)
            priv = self.kvpool.alloc(total_pages - len(shared))
        if priv is None:
            self.radix.release(node)
            # un-count this attempt; the request stays at the queue head
            self._prompt_tokens -= len(ids)
            if req.truncated_tokens:
                self._truncated_tokens -= req.truncated_tokens
                self._truncated_requests -= 1
            return False
        req.prefix_hit_tokens = prefix_len
        self._prefix_hit_tokens += prefix_len
        bt = kvpool.block_table_array([shared + priv], self._bt_width)
        first = None
        plan = self._chunk_plan(len(ids) - prefix_len, prefix_len)
        for ci, (off, real, padded) in enumerate(plan):
            start = prefix_len + off
            tokens, positions = self._chunk_batch(
                ids[start:start + real], start, padded)
            self._rng, k = jax.random.split(self._rng)
            self._pad_tokens += padded - real
            self._extend_shapes.add((padded, self.cfg.modality))
            self._extend_chunks += 1
            self.cache, tok = self._jit_extend_paged(
                self.params, self.cache, tokens, positions, bt,
                jnp.int32(start), jnp.int32(real), k,
                jnp.float32(req.temperature), jnp.int32(req.top_k),
                sample=ci == len(plan) - 1)
            if ci == len(plan) - 1:
                first = tok
        slot.request = req
        slot.cache_len = len(ids)
        slot.remaining = req.max_new_tokens - 1
        slot.generated = [int(first)]                     # one host sync
        slot.token_ids = ids
        slot.pages_shared = shared
        slot.pages_priv = priv
        slot.node = node
        self._arm_spec(slot, ids)
        self._bt_device = None          # slot membership changed
        self._prefill_syncs += 1
        self._group_queue(ids)
        return True

    def _capture_snapshot(self, si: int) -> int:
        """Splice slot ``si``'s current state into a fresh arena row.
        Returns the slot id, or -1 when the arena stays full even after LRU
        trie eviction (every row backs a pinned path) — the capture is then
        skipped; correctness is untouched, only future hit depth."""
        sid = self.snaps.alloc()
        if sid is None:
            self.snaps.free(self.radix.evict_snaps(1))
            sid = self.snaps.alloc()
        if sid is None:
            return -1
        self.snap_arena = self._jit_snap_capture(self.snap_arena, self.cache,
                                                 jnp.int32(sid),
                                                 jnp.int32(si))
        self._snap_captures += 1
        return sid

    def _admit_snap(self, si: int, slot: _Slot, req: Request):
        """Snapshot-mode admission (stateful archs under cache_mode="paged"):
        radix-match the prompt, restore the nearest per-prefix state
        snapshot into the slot, and prefill only the suffix — capturing new
        snapshots at every ``snap_stride``-block boundary along the way and
        adopting them into the trie immediately, so the rest of THIS engine
        step's grouped admissions already reuse them. Never fails: snapshots
        take no pages, and a full arena only skips captures."""
        ids = self._encode_prompt(req)
        ps = self.engine_cfg.page_size
        # always recompute at least the last prompt token (its logits seed
        # the first sampled token), so cap the usable match one token short
        _, node = self.radix.match(ids[:len(ids) - 1])
        sid, sblocks = self.radix.nearest_snapshot(node)
        restore = sblocks * ps
        if sid >= 0:
            self.cache = self._jit_snap_restore(self.cache, self.snap_arena,
                                                jnp.int32(sid), jnp.int32(si))
            self._snap_hits += 1
        else:
            self._snap_misses += 1
        req.prefix_hit_tokens = restore
        self._prefix_hit_tokens += restore
        stride = ps * max(1, self.engine_cfg.snap_stride)
        bounds = set(range((restore // stride + 1) * stride,
                           len(ids) + 1, stride))
        new_snaps = {}
        pos, first = restore, None
        for end in sorted(bounds | {len(ids)}):
            first = self._prefill_span(si, req, ids, pos, end,
                                       sample=end == len(ids))
            if end in bounds:
                s = self._capture_snapshot(si)
                if s >= 0:
                    new_snaps[end // ps] = s
            pos = end
        if new_snaps:
            hi = max(new_snaps) * ps
            self.snaps.free(self.radix.insert_snaps(ids[:hi], new_snaps))
        slot.request = req
        slot.cache_len = len(ids)
        slot.remaining = req.max_new_tokens - 1
        slot.generated = [int(first)]                     # one host sync
        slot.token_ids = ids
        slot.node = node
        self._arm_spec(slot, ids)
        self._prefill_syncs += 1
        self._group_queue(ids)
        return True

    def _arm_spec(self, slot: _Slot, ids: List[int]):
        """Index the request's context for the n-gram drafter (prompt + the
        first sampled token; decode/verify commits extend it)."""
        if not self.spec:
            return
        slot.drafter = NgramDrafter(ids + slot.generated,
                                    n_min=self.engine_cfg.spec_ngram_min,
                                    n_max=self.engine_cfg.spec_ngram_max)
        slot.spec_on = True

    def _group_queue(self, ids: List[int]):
        """Radix-aware admission batching (paged): stable-move queued
        requests whose (truncated) prompt shares the just-admitted prompt's
        first radix block to the queue front, so the remaining free slots of
        THIS engine step admit them while the shared prefix pages are pinned
        and hot — N agents sharing a system prompt prefill it once and join
        the same decode batch. FIFO order survives within the group and the
        remainder."""
        ps = self.engine_cfg.page_size
        # queue[0] is the request being admitted right now — skip it
        if len(ids) < ps or len(self._queue) < 2:
            return
        head = tuple(ids[:ps])
        grouped, rest = [], []
        for r in list(self._queue)[1:]:
            if r._ids is None:
                r._ids = self.tokenizer.encode(r.prompt)
            rids = r._ids[-(self.capacity - r.max_new_tokens - 1):]
            if len(rids) >= ps and tuple(rids[:ps]) == head:
                r._grouped = True
                grouped.append(r)
            else:
                rest.append(r)
        if grouped:
            self._queue = collections.deque(
                [self._queue[0]] + grouped + rest)

    def _admit(self):
        """Prefill queued requests into free slots (continuous batching).

        Paged mode admits FIFO: if the pool can't cover the head request the
        whole admission round stops (no smaller request jumps the line), and
        the head retries next step once decode frees pages.
        """
        for si, slot in enumerate(self.slots):
            if slot.request is not None or not self._queue:
                continue
            req = self._queue[0]
            t0 = time.perf_counter()
            admit = (self._admit_paged if self.paged else
                     self._admit_snap if self.snapshots else
                     self._admit_dense)
            admitted = admit(si, slot, req)
            if not admitted:
                if not self._active():
                    raise RuntimeError(
                        f"paged KV pool too small: request rid={req.rid} "
                        f"needs more pages than the pool can ever free "
                        f"(num_pages={self.kvpool.num_pages}, "
                        f"page_size={self.engine_cfg.page_size})")
                break
            self._queue.popleft()
            if req._grouped:
                self._grouped_admissions += 1
                req._grouped = False
            req.admit_index = self._next_admit
            self._next_admit += 1
            req.prefill_s += time.perf_counter() - t0
        # grouping credit is same-step only: a sharer still queued when the
        # round ends admits later on its own (the pinned pages may be gone)
        for r in self._queue:
            r._grouped = False

    def _active(self):
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def _finalize(self, si: int):
        slot = self.slots[si]
        req = slot.request
        req.output_tokens = len(slot.generated)
        req.output_text = self.tokenizer.decode(slot.generated)
        req.latency_s = time.perf_counter() - req._submit_t
        if self.paged:
            # donate the finished sequence's complete pages to the radix tree
            # (prompt + generated tokens: the next agent turn's prompt embeds
            # this whole conversation, so it will match deep), free the rest
            all_tokens = slot.token_ids + slot.generated
            kv_cover = slot.cache_len          # positions actually written
            ps = self.engine_cfg.page_size
            n_complete = min(kv_cover, len(all_tokens)) // ps
            bt_pages = slot.pages_shared + slot.pages_priv
            rejected = self.radix.insert(all_tokens[:n_complete * ps],
                                         bt_pages[:n_complete])
            self.kvpool.free(rejected + bt_pages[n_complete:])
            self.radix.release(slot.node)
            self._bt_device = None      # slot membership changed
        elif self.snapshots:
            # snapshots were adopted into the trie at admission (and the
            # end-of-generation state is not block-aligned, so there is
            # nothing further to donate) — just unpin the matched node
            self.radix.release(slot.node)
        self.slots[si] = _Slot()

    # ---- speculative decode pass -------------------------------------------
    def _spec_pass(self, active) -> set:
        """One speculative verify pass, interleaved with the chunked-decode
        loop: slots whose drafter has a proposal verify it this step; the
        returned set sits out the decode chunk. Falls back to plain chunked
        decode (empty set) when no slot has a draft, so non-copyable
        workloads pay nothing but the host-side n-gram lookups."""
        eos = self.tokenizer.eos_id
        live = []
        for i in active:
            s = self.slots[i]
            # same conditions the decode loop's entry done-mask would catch
            if (s.remaining <= 0 or s.cache_len >= self.capacity - 1
                    or s.generated[-1] == eos):
                self._finalize(i)
                continue
            live.append(i)
        if not live:
            return set(active)
        drafts = {}
        for i in live:
            s = self.slots[i]
            d = []
            if s.spec_on:
                # the +1 correction/bonus token must fit the budget and the
                # capacity window, and draft writes must stay in bounds
                cap = min(self.engine_cfg.spec_len, s.remaining - 1,
                          self.capacity - 2 - s.cache_len)
                if cap > 0:
                    d = s.drafter.draft(cap)
            drafts[i] = d
        drafted = [i for i in live if drafts[i]]
        if not drafted:
            return set()
        # only drafted slots verify; the rest keep the chunked decode loop
        # (a disabled or draftless slot must not degrade to one-token steps)
        self._spec_step_batched(drafted, drafts)
        return set(drafted)

    def _spec_step_batched(self, live, drafts):
        """ONE jit'd verify forward scores every drafted slot's proposal at
        once, for every arch (rows of undrafted slots carry lens=0 — no
        reads, no writes, no commits). Rollback: linear full-attention K/V
        is masked by cache position until overwritten; recurrent / conv /
        xLSTM / ring-KV state rewinds to each row's accepted length inside
        the same jit (``model.verify_commit``)."""
        t0 = time.perf_counter()
        S = self.engine_cfg.spec_len + 1
        tok_rows = [[0] * S for _ in range(self.num_slots)]
        lens = [0] * self.num_slots
        for i in live:
            s = self.slots[i]
            row = [s.generated[-1]] + drafts[i]
            lens[i] = len(row)
            tok_rows[i][:len(row)] = row
        tokens = jnp.asarray(tok_rows, jnp.int32)
        lens_a = jnp.asarray(lens, jnp.int32)
        clens = jnp.asarray([s.cache_len for s in self.slots], jnp.int32)
        # the same greedy/temps/top-k static specialization as the decode loop
        sampling = any(self.slots[i].request.temperature > 0.0 for i in live)
        temps = (jnp.asarray([s.request.temperature if s.request else 0.0
                              for s in self.slots], jnp.float32)
                 if sampling else None)
        top_ks = (jnp.asarray([s.request.top_k if s.request else 0
                               for s in self.slots], jnp.int32)
                  if sampling and any(self.slots[i].request.top_k > 0
                                      for i in live)
                  else None)
        self._rng, k = jax.random.split(self._rng)
        bt = None
        if self.paged:
            if self._bt_device is None:
                self._bt_device = kvpool.block_table_array(
                    [(s.pages_shared + s.pages_priv) if s.request else []
                     for s in self.slots], self._bt_width)
            bt = self._bt_device
        self.cache, out_tok, out_len = self._jit_verify(
            self.params, self.cache, tokens, clens, lens_a, temps, top_ks,
            k, bt)
        # the ONE host sync of the verify step
        out_tok, out_len = jax.device_get((out_tok, out_len))
        self._decode_syncs += 1
        self._verify_steps += 1
        dt = time.perf_counter() - t0
        for i in live:
            self._commit_spec(i, drafts[i], out_tok[i], int(out_len[i]),
                              dt / len(live))

    def _commit_spec(self, si, draft, out_row, n, dt):
        """Commit one slot's verify outcome: n = accepted drafts + 1
        correction/bonus token, truncated at the first EOS."""
        slot = self.slots[si]
        eos = self.tokenizer.eos_id
        emitted = [int(t) for t in out_row[:n]]
        for j, t in enumerate(emitted):
            if t == eos:
                emitted = emitted[:j + 1]
                break
        slot.generated.extend(emitted)
        slot.drafter.extend(emitted)
        slot.cache_len += len(emitted)
        slot.remaining -= len(emitted)
        slot.spec_drafted += len(draft)
        slot.spec_accepted += n - 1
        self._draft_tokens += len(draft)
        self._accepted_tokens += n - 1
        self._decode_tokens += len(emitted)
        slot.request.decode_s += dt
        ecfg = self.engine_cfg
        if (slot.spec_on and slot.spec_drafted >= ecfg.spec_warmup
                and slot.spec_accepted <
                ecfg.spec_min_accept * slot.spec_drafted):
            slot.spec_on = False        # this request isn't n-gram-predictable
        if (slot.remaining <= 0 or slot.generated[-1] == eos
                or slot.cache_len >= self.capacity - 1):
            self._finalize(si)

    def step(self):
        """One engine iteration: admit, then one speculative verify pass for
        slots with drafts (when spec is on) and/or one chunked decode for
        the rest."""
        self._admit()
        active = self._active()
        if not active:
            return False
        handled = self._spec_pass(active) if self.spec else set()
        rest = [i for i in self._active() if i not in handled]
        if not rest:
            return True
        t0 = time.perf_counter()
        last = jnp.asarray([s.generated[-1] if s.request else 0
                            for s in self.slots], jnp.int32)
        clens = jnp.asarray([s.cache_len for s in self.slots], jnp.int32)
        rem = jnp.asarray([s.remaining for s in self.slots], jnp.int32)
        # spec-handled slots sit this chunk out via the done mask (they
        # already advanced up to spec_len+1 tokens this step)
        done = jnp.asarray([i in handled or s.request is None
                            or s.remaining <= 0
                            or s.cache_len >= self.capacity - 1
                            or s.generated[-1] == self.tokenizer.eos_id
                            for i, s in enumerate(self.slots)], bool)
        # static specialization: an all-greedy batch (the common agent case)
        # compiles a loop body with no RNG split / categorical / top-k sort —
        # jit re-specializes on the None-vs-array structure, so at most three
        # decode variants ever compile (greedy / temps / temps+top-k)
        sampling = any(s.request.temperature > 0.0
                       for s in self.slots if s.request)
        temps = (jnp.asarray([s.request.temperature if s.request else 0.0
                              for s in self.slots], jnp.float32)
                 if sampling else None)
        top_ks = (jnp.asarray([s.request.top_k if s.request else 0
                               for s in self.slots], jnp.int32)
                  if sampling and any(s.request.top_k > 0
                                      for s in self.slots if s.request)
                  else None)
        self._rng, k = jax.random.split(self._rng)
        # paged: the chunk's writes route through per-slot block tables
        # (admission reserved pages for the whole token budget, so the table
        # only changes when slot membership does — cached on device between
        # chunks); empty/done slots point at the trash page. jit
        # re-specializes on None-vs-array, like temps above.
        bt = None
        if self.paged:
            if self._bt_device is None:
                self._bt_device = kvpool.block_table_array(
                    [(s.pages_shared + s.pages_priv) if s.request else []
                     for s in self.slots], self._bt_width)
            bt = self._bt_device

        self.cache, tok_buf, emit_buf, clens, rem, done = \
            self._jit_decode_chunk(self.params, self.cache, last, clens, rem,
                                   done, temps, top_ks, k, bt)
        # the ONE host sync of the chunk: pull tokens + masks + slot state
        tok_buf, emit_buf, clens_h, rem_h, done_h = jax.device_get(
            (tok_buf, emit_buf, clens, rem, done))
        self._decode_syncs += 1
        self._decode_chunks += 1
        dt = time.perf_counter() - t0

        emitted = 0
        for i in rest:
            slot = self.slots[i]
            new = tok_buf[:, i][emit_buf[:, i]]
            slot.generated.extend(int(t) for t in new)
            if slot.drafter is not None and new.size:
                slot.drafter.extend([int(t) for t in new])
            emitted += int(new.size)
            slot.cache_len = int(clens_h[i])
            slot.remaining = int(rem_h[i])
            slot.request.decode_s += dt / max(len(rest), 1)
        self._decode_tokens += emitted
        for i in rest:
            if bool(done_h[i]):
                self._finalize(i)
        return True

    def run_until_drained(self):
        while self.step() or self._queue:
            pass
