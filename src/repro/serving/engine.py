"""Serving engine: slot-based continuous batching over prefill/decode steps.

The engine owns a fixed decode batch of ``num_slots`` sequences sharing one
ring KV cache (per-slot cache rows). Requests queue up; free slots are
prefilled (chunked) and join the in-flight decode batch; finished slots are
released to the next request — continuous batching, the vLLM/MaxText serving
idiom, expressed with jit-compiled prefill/decode steps.

On CPU it runs reduced configs end-to-end (agents in examples/serve_agents.py
talk to it); on the production mesh the same functions lower through
launch/dryrun.py (prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.serving.sampler import sample
from repro.serving.tokenizer import ByteTokenizer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 64
    temperature: float = 0.0
    # filled by the engine
    prompt_tokens: int = 0
    output_text: str = ""
    output_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    cache_len: int = 0
    remaining: int = 0
    generated: Optional[list] = None


class ServingEngine:
    def __init__(self, cfg, *, num_slots: int = 4, capacity: int = 512,
                 params=None, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.tokenizer = ByteTokenizer(cfg.vocab_size)
        self.num_slots = num_slots
        self.capacity = capacity
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.cache = self.model.init_cache(num_slots, capacity)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.cache_lens = jnp.zeros((num_slots,), jnp.int32)
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._rng = jax.random.PRNGKey(seed + 1)
        self._next_rid = 0

        # jit entry points (per-slot prefill via batch=1 view, shared decode)
        self._jit_decode = jax.jit(self._decode_step_fn)
        self._jit_prefill = jax.jit(self._prefill_fn)

    # ---- jit'd computations ------------------------------------------------
    def _prefill_fn(self, params, tokens, positions):
        cache1 = self.model.init_cache(1, self.capacity)
        batch = {("frames" if self.cfg.modality == "audio_frames" else "tokens"): tokens,
                 "positions": positions}
        logits, cache1 = self.model.prefill(params, batch, cache1)
        return logits[:, -1], cache1

    def _decode_step_fn(self, params, cache, tokens, positions, cache_len):
        batch = {"tokens": tokens, "positions": positions}
        logits, cache = self.model.decode_step(params, batch, cache, cache_len)
        return logits[:, 0], cache

    # ---- public API -----------------------------------------------------------
    def submit(self, prompt: str, *, max_new_tokens: int = 64,
               temperature: float = 0.0) -> Request:
        self._next_rid += 1
        req = Request(self._next_rid, prompt, max_new_tokens, temperature)
        self._queue.put(req)
        return req

    def generate(self, prompt: str, *, max_new_tokens: int = 64,
                 temperature: float = 0.0) -> str:
        req = self.submit(prompt, max_new_tokens=max_new_tokens,
                          temperature=temperature)
        self.run_until_drained()
        return req.output_text

    # ---- engine loop --------------------------------------------------------
    def _admit(self):
        """Prefill queued requests into free slots (continuous batching)."""
        for si, slot in enumerate(self.slots):
            if slot.request is not None or self._queue.empty():
                continue
            req = self._queue.get()
            t0 = time.perf_counter()
            ids = self.tokenizer.encode(req.prompt)[-(self.capacity - req.max_new_tokens - 1):]
            req.prompt_tokens = len(ids)
            tokens = jnp.asarray([ids], jnp.int32)
            positions = jnp.arange(len(ids), dtype=jnp.int32)[None, :]
            if self.cfg.modality == "audio_frames":
                # modality stub: frame embeddings stand in for token ids
                tokens = jax.nn.one_hot(tokens % self.cfg.d_model, self.cfg.d_model,
                                        dtype=jnp.dtype(self.cfg.dtype))
            last_logits, cache1 = self._jit_prefill(self.params, tokens, positions)
            # copy the single-row cache into slot si of the shared cache;
            # scan caches are [L, B, ...] (batch dim 1), tail caches [B, ...]
            def _scan_leaf(full, one):
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype), (0, si) + (0,) * (full.ndim - 2))

            def _tail_leaf(full, one):
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype), (si,) + (0,) * (full.ndim - 1))

            self.cache = {
                k: jax.tree.map(_scan_leaf if k == "scan" else _tail_leaf,
                                self.cache[k], cache1[k])
                for k in self.cache}
            self.cache_lens = self.cache_lens.at[si].set(len(ids))
            slot.request = req
            slot.cache_len = len(ids)
            slot.remaining = req.max_new_tokens
            self._rng, k = jax.random.split(self._rng)
            first = sample(last_logits, k, temperature=req.temperature,
                           vocab_limit=self.cfg.vocab_size)
            slot.generated = [int(first[0])]
            slot.remaining -= 1
            req.prefill_s += time.perf_counter() - t0

    def _active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def step(self):
        """One engine iteration: admit + one fused decode step for all slots."""
        self._admit()
        active = self._active()
        if not active:
            return False
        t0 = time.perf_counter()
        last = [self.slots[i].generated[-1] if self.slots[i].request else 0
                for i in range(self.num_slots)]
        tokens = jnp.asarray(last, jnp.int32)[:, None]
        positions = self.cache_lens[:, None]
        logits, self.cache = self._jit_decode(self.params, self.cache, tokens,
                                              positions, self.cache_lens)
        self._rng, k = jax.random.split(self._rng)
        nxt = sample(logits, k, temperature=0.0, vocab_limit=self.cfg.vocab_size)
        dt = time.perf_counter() - t0
        self.cache_lens = self.cache_lens + jnp.asarray(
            [1 if s.request else 0 for s in self.slots], jnp.int32)
        for i in active:
            slot = self.slots[i]
            slot.generated.append(int(nxt[i]))
            slot.cache_len += 1
            slot.remaining -= 1
            slot.request.decode_s += dt / max(len(active), 1)
            done = (slot.remaining <= 0
                    or slot.generated[-1] == self.tokenizer.eos_id
                    or slot.cache_len >= self.capacity - 1)
            if done:
                req = slot.request
                req.output_tokens = len(slot.generated)
                req.output_text = self.tokenizer.decode(slot.generated)
                self.slots[i] = _Slot()
                self.cache_lens = self.cache_lens.at[i].set(0)
        return True

    def run_until_drained(self):
        while self.step() or not self._queue.empty():
            pass
