"""Deprecated serving façade — the engine now lives in two layers.

The 1200-line monolith this module used to hold was split:

* **serving/scheduler.py** — the scheduler layer: request queue (FIFO within
  priority classes), slot lifecycle, paged/radix/snapshot bookkeeping,
  sessions, cancellation, stop sequences, per-request RNG, ``stats()``.
* **serving/programs.py** — the jit-program layer: bucketed prefill, extend
  continuations, the chunked decode loop, the fused speculative verify, the
  snapshot splices.

New code should use the session-oriented frontend,
``repro.serving.server.LLMServer`` (``open_session()`` / ``submit() ->
Handle`` / ``handle.stream()`` / ``cancel()``), with per-request parameters
in a ``SamplingParams`` — see docs/serving.md. Both frontends take
``EngineConfig(mesh=...)`` to shard the programs and cache pools over a JAX
device mesh (bit-identical greedy outputs; docs/serving.md §Sharded
serving). ``ServingEngine`` remains as
a thin deprecation shim so existing callers and the A/B benchmarks keep
working: ``submit(prompt, **kwargs)`` forwards to
``Scheduler.enqueue(prompt, SamplingParams(...))`` and warns.
"""
from __future__ import annotations

import warnings

from repro.serving.programs import auto_buckets as _auto_buckets  # noqa: F401
from repro.serving.scheduler import (EngineConfig, Request,  # noqa: F401
                                     SamplingParams, Scheduler)


class ServingEngine(Scheduler):
    """Back-compat engine: the pre-redesign blocking API over the scheduler.

    Everything an existing caller touched (``slots``, ``stats()``,
    ``run_until_drained()``, ``kvpool`` / ``radix`` / ``snaps``, ...) is
    inherited unchanged from ``Scheduler``; only the kwargs-style
    ``submit``/``generate`` entry points are deprecated.
    """

    def submit(self, prompt: str, *, max_new_tokens: int = 64,
               temperature: float = 0.0, top_k: int = 0) -> Request:
        warnings.warn(
            "ServingEngine.submit(prompt, **kwargs) is deprecated; use "
            "repro.serving.server.LLMServer with SamplingParams",
            DeprecationWarning, stacklevel=2)
        return self.enqueue(prompt, SamplingParams(
            max_new_tokens=max_new_tokens, temperature=temperature,
            top_k=top_k))

    def generate(self, prompt: str, *, max_new_tokens: int = 64,
                 temperature: float = 0.0, top_k: int = 0) -> str:
        req = self.submit(prompt, max_new_tokens=max_new_tokens,
                          temperature=temperature, top_k=top_k)
        self.run_until_drained()
        if req.status == "failed":
            # the fault layer dead-letters instead of crashing the pump;
            # the blocking API surfaces the error to its caller directly
            raise req.error
        return req.output_text
