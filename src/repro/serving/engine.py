"""Serving engine: slot-based continuous batching over a sync-free fast path.

The engine owns a fixed decode batch of ``num_slots`` sequences sharing one
ring KV cache (per-slot cache rows). Requests queue up; free slots are
prefilled and join the in-flight decode batch; finished slots are released to
the next request — continuous batching, the vLLM/MaxText serving idiom.

Fast-path structure (see benchmarks/serving_bench.py for the measurements):

* **Bucketed prefill** — prompts are right-padded to a small set of length
  buckets, so the prefill function compiles once per bucket instead of once
  per distinct prompt length. The per-slot cache splice happens *inside* the
  jit (``dynamic_update_slice`` at the slot index, donated shared cache), not
  as a host-side tree-map copy.
* **Chunked decode** — a jit'd ``lax.while_loop`` decodes up to
  ``decode_chunk`` tokens per engine step with a per-slot done mask
  (EOS / token budget / capacity), sampling on device with per-slot
  temperature / top-k (``sampler.sample_batched``). The host syncs at most
  once per chunk, not once per token.
* **Aligned cache** — cache capacity is rounded up to the decode-attention
  kernel block (``block_w``), so the Pallas kernel never re-pads the cache.

On CPU it runs reduced configs end-to-end (agents in examples/serve_agents.py
talk to it); on the production mesh the same functions lower through
launch/dryrun.py (prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.serving.sampler import sample_batched
from repro.serving.tokenizer import ByteTokenizer


def _auto_buckets(capacity: int, lo: int = 32) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to (and including) capacity."""
    buckets = []
    b = min(lo, capacity)
    while b < capacity:
        buckets.append(b)
        b *= 2
    buckets.append(capacity)
    return tuple(buckets)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving fast-path knobs.

    prefill_buckets: explicit bucket lengths; None → auto powers-of-two;
                     empty tuple → exact-length prefill (one compile per
                     distinct prompt length — the pre-fast-path behaviour,
                     kept for A/B benchmarking).
    decode_chunk:    decode tokens per jit'd inner loop (1 → one host sync
                     per token, the pre-fast-path behaviour). All-greedy
                     batches additionally compile a sampler-free loop body
                     (no per-step RNG / top-k sort).
    block_w:         decode-attention KV block; cache capacity is rounded up
                     to a multiple of it so the kernel never re-pads.
    donate:          donate the shared cache to prefill/decode jits
                     (None → auto: on everywhere except CPU, where XLA
                     ignores donation and warns).
    """
    prefill_buckets: Optional[Tuple[int, ...]] = None
    decode_chunk: int = 16
    block_w: int = 256
    donate: Optional[bool] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: int = 0
    # filled by the engine
    prompt_tokens: int = 0
    output_text: str = ""
    output_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    latency_s: float = 0.0
    admit_index: int = -1
    _submit_t: float = 0.0


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    cache_len: int = 0
    remaining: int = 0
    generated: Optional[list] = None


class ServingEngine:
    def __init__(self, cfg, *, num_slots: int = 4, capacity: int = 512,
                 params=None, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None):
        self.engine_cfg = engine_cfg or EngineConfig()
        if self.engine_cfg.decode_chunk < 1:
            raise ValueError(
                f"decode_chunk must be >= 1, got {self.engine_cfg.decode_chunk} "
                "(a zero-length chunk makes no progress)")
        bw = max(1, self.engine_cfg.block_w)
        if capacity > bw:
            capacity = -(-capacity // bw) * bw      # align to kernel block
        self.cfg = dataclasses.replace(cfg, decode_block_w=bw)
        self.model = Model(self.cfg)
        self.tokenizer = ByteTokenizer(cfg.vocab_size)
        self.num_slots = num_slots
        self.capacity = capacity
        buckets = self.engine_cfg.prefill_buckets
        self.buckets: Tuple[int, ...] = (_auto_buckets(capacity)
                                         if buckets is None else
                                         tuple(sorted(buckets)))
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.cache = self.model.init_cache(num_slots, capacity)
        self.slots = [_Slot() for _ in range(num_slots)]
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._rng = jax.random.PRNGKey(seed + 1)
        self._next_rid = 0
        self._next_admit = 0

        # perf counters (benchmarks/serving_bench.py reads these)
        self._prefill_shapes: set = set()        # 1 jit compile per entry
        self._decode_syncs = 0                   # blocking pulls in decode
        self._prefill_syncs = 0                  # blocking pulls at admission
        self._decode_tokens = 0
        self._decode_chunks = 0

        donate = self.engine_cfg.donate
        if donate is None:
            donate = jax.default_backend() != "cpu"
        dargs = (1,) if donate else ()
        self._jit_prefill = jax.jit(self._prefill_fn, donate_argnums=dargs)
        self._jit_decode_chunk = jax.jit(self._decode_chunk_fn,
                                         donate_argnums=dargs)

    # ---- jit'd computations ------------------------------------------------
    def _prefill_fn(self, params, cache, tokens, positions, slot, length, key,
                    temperature, top_k):
        """Prefill one (padded) prompt and splice it into the shared cache.

        Everything — forward pass, per-slot cache splice, first-token sample —
        happens in one jit, compiled once per bucket length.
        """
        cache1 = self.model.init_cache(1, self.capacity)
        batch = {("frames" if self.cfg.modality == "audio_frames" else "tokens"): tokens,
                 "positions": positions}
        logits, cache1 = self.model.prefill(params, batch, cache1, length=length)
        last = jax.lax.dynamic_index_in_dim(logits, length - 1, axis=1,
                                            keepdims=False)          # [1, V]
        tok = sample_batched(last, key, temperature=temperature[None],
                             top_k=top_k[None], vocab_limit=self.cfg.vocab_size)

        # splice the single-row cache into slot `slot` of the shared cache;
        # scan caches are [L, B, ...] (batch dim 1), tail caches [B, ...]
        def _scan_leaf(full, one):
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype),
                (jnp.int32(0), slot) + (jnp.int32(0),) * (full.ndim - 2))

        def _tail_leaf(full, one):
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype),
                (slot,) + (jnp.int32(0),) * (full.ndim - 1))

        cache = {k: jax.tree.map(_scan_leaf if k == "scan" else _tail_leaf,
                                 cache[k], cache1[k])
                 for k in cache}
        return cache, tok[0]

    def _decode_chunk_fn(self, params, cache, last_tok, cache_lens, remaining,
                         done, temps, top_ks, key):
        """Decode up to ``decode_chunk`` tokens for every live slot on device.

        Per-slot done mask (EOS / budget / capacity); finished or empty slots
        keep running in the fixed batch but stop emitting and stop advancing
        their cache row. Returns everything the host needs in one pull.
        """
        chunk = self.engine_cfg.decode_chunk
        B = self.num_slots
        eos = self.tokenizer.eos_id
        tok_buf = jnp.zeros((chunk, B), jnp.int32)
        emit_buf = jnp.zeros((chunk, B), bool)

        def cond(st):
            i = st[0]
            return (i < chunk) & jnp.any(~st[5])

        def body(st):
            i, cache, last, clens, rem, done, key, tb, eb = st
            batch = {"tokens": last[:, None], "positions": clens[:, None]}
            logits, cache = self.model.decode_step(params, batch, cache, clens)
            if temps is None:                   # statically greedy batch:
                sub = key                       # no RNG / sort in the loop
            else:
                key, sub = jax.random.split(key)
            nxt = sample_batched(logits[:, 0], sub, temperature=temps,
                                 top_k=top_ks, vocab_limit=self.cfg.vocab_size)
            emit = ~done
            last = jnp.where(emit, nxt, last)
            clens = clens + emit.astype(jnp.int32)
            rem = rem - emit.astype(jnp.int32)
            done = done | (emit & ((rem <= 0) | (nxt == eos)
                                   | (clens >= self.capacity - 1)))
            tb = tb.at[i].set(jnp.where(emit, nxt, 0))
            eb = eb.at[i].set(emit)
            return (i + 1, cache, last, clens, rem, done, key, tb, eb)

        st = (jnp.int32(0), cache, last_tok, cache_lens, remaining, done,
              key, tok_buf, emit_buf)
        _, cache, last_tok, cache_lens, remaining, done, _, tok_buf, emit_buf = \
            jax.lax.while_loop(cond, body, st)
        return cache, tok_buf, emit_buf, cache_lens, remaining, done

    # ---- public API -----------------------------------------------------------
    def submit(self, prompt: str, *, max_new_tokens: int = 64,
               temperature: float = 0.0, top_k: int = 0) -> Request:
        if max_new_tokens >= self.capacity - 1:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no room for the "
                f"prompt in a capacity-{self.capacity} cache "
                f"(need max_new_tokens <= capacity - 2)")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        self._next_rid += 1
        req = Request(self._next_rid, prompt, max_new_tokens, temperature,
                      top_k)
        req._submit_t = time.perf_counter()
        self._queue.put(req)
        return req

    def generate(self, prompt: str, *, max_new_tokens: int = 64,
                 temperature: float = 0.0, top_k: int = 0) -> str:
        req = self.submit(prompt, max_new_tokens=max_new_tokens,
                          temperature=temperature, top_k=top_k)
        self.run_until_drained()
        return req.output_text

    def stats(self) -> dict:
        toks = max(self._decode_tokens, 1)
        return {
            "prefill_compiles": len(self._prefill_shapes),
            "prefill_buckets": list(self.buckets),
            "decode_chunk": self.engine_cfg.decode_chunk,
            "decode_tokens": self._decode_tokens,
            "decode_chunks": self._decode_chunks,
            "host_syncs": self._decode_syncs,
            "host_syncs_per_token": self._decode_syncs / toks,
            # admission also pulls the first sampled token (once per request,
            # not per token) — reported separately so the decode-path sync
            # rate above stays honest
            "prefill_syncs": self._prefill_syncs,
        }

    # ---- engine loop --------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n                        # exact-length (legacy) mode

    def _admit(self):
        """Prefill queued requests into free slots (continuous batching)."""
        for si, slot in enumerate(self.slots):
            if slot.request is not None or self._queue.empty():
                continue
            req = self._queue.get()
            t0 = time.perf_counter()
            window = self.capacity - req.max_new_tokens - 1   # >= 1 (submit guard)
            ids = self.tokenizer.encode(req.prompt)[-window:]
            req.prompt_tokens = len(ids)
            bucket = self._bucket_for(len(ids))
            padded = ids + [self.tokenizer.pad_id] * (bucket - len(ids))
            tokens = jnp.asarray([padded], jnp.int32)
            positions = jnp.arange(bucket, dtype=jnp.int32)[None, :]
            if self.cfg.modality == "audio_frames":
                # modality stub: frame embeddings stand in for token ids
                tokens = jax.nn.one_hot(tokens % self.cfg.d_model, self.cfg.d_model,
                                        dtype=jnp.dtype(self.cfg.dtype))
            self._rng, k = jax.random.split(self._rng)
            self._prefill_shapes.add((bucket, self.cfg.modality))
            self.cache, first = self._jit_prefill(
                self.params, self.cache, tokens, positions,
                jnp.int32(si), jnp.int32(len(ids)), k,
                jnp.float32(req.temperature), jnp.int32(req.top_k))
            slot.request = req
            slot.cache_len = len(ids)
            slot.remaining = req.max_new_tokens - 1
            slot.generated = [int(first)]                     # one host sync
            self._prefill_syncs += 1
            req.admit_index = self._next_admit
            self._next_admit += 1
            req.prefill_s += time.perf_counter() - t0

    def _active(self):
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def _finalize(self, si: int):
        slot = self.slots[si]
        req = slot.request
        req.output_tokens = len(slot.generated)
        req.output_text = self.tokenizer.decode(slot.generated)
        req.latency_s = time.perf_counter() - req._submit_t
        self.slots[si] = _Slot()

    def step(self):
        """One engine iteration: admit + one chunked decode for all slots."""
        self._admit()
        active = self._active()
        if not active:
            return False
        t0 = time.perf_counter()
        last = jnp.asarray([s.generated[-1] if s.request else 0
                            for s in self.slots], jnp.int32)
        clens = jnp.asarray([s.cache_len for s in self.slots], jnp.int32)
        rem = jnp.asarray([s.remaining for s in self.slots], jnp.int32)
        done = jnp.asarray([s.request is None or s.remaining <= 0
                            or s.cache_len >= self.capacity - 1
                            or s.generated[-1] == self.tokenizer.eos_id
                            for s in self.slots], bool)
        # static specialization: an all-greedy batch (the common agent case)
        # compiles a loop body with no RNG split / categorical / top-k sort —
        # jit re-specializes on the None-vs-array structure, so at most three
        # decode variants ever compile (greedy / temps / temps+top-k)
        sampling = any(s.request.temperature > 0.0
                       for s in self.slots if s.request)
        temps = (jnp.asarray([s.request.temperature if s.request else 0.0
                              for s in self.slots], jnp.float32)
                 if sampling else None)
        top_ks = (jnp.asarray([s.request.top_k if s.request else 0
                               for s in self.slots], jnp.int32)
                  if sampling and any(s.request.top_k > 0
                                      for s in self.slots if s.request)
                  else None)
        self._rng, k = jax.random.split(self._rng)

        self.cache, tok_buf, emit_buf, clens, rem, done = \
            self._jit_decode_chunk(self.params, self.cache, last, clens, rem,
                                   done, temps, top_ks, k)
        # the ONE host sync of the chunk: pull tokens + masks + slot state
        tok_buf, emit_buf, clens_h, rem_h, done_h = jax.device_get(
            (tok_buf, emit_buf, clens, rem, done))
        self._decode_syncs += 1
        self._decode_chunks += 1
        dt = time.perf_counter() - t0

        emitted = 0
        for i in active:
            slot = self.slots[i]
            new = tok_buf[:, i][emit_buf[:, i]]
            slot.generated.extend(int(t) for t in new)
            emitted += int(new.size)
            slot.cache_len = int(clens_h[i])
            slot.remaining = int(rem_h[i])
            slot.request.decode_s += dt / max(len(active), 1)
        self._decode_tokens += emitted
        for i in active:
            if bool(done_h[i]):
                self._finalize(i)
        return True

    def run_until_drained(self):
        while self.step() or not self._queue.empty():
            pass
