"""Radix (token-trie) index over shared KV-cache pages.

Maps token-id prefixes of past requests to chains of KV pages in the paged
pool (serving/kvpool.py), at page granularity: each trie edge is one
``page_size``-token block, each node owns exactly one page holding that
block's K/V. A new request walks the trie with its prompt and reuses every
matched page without re-prefilling it — the vLLM / SGLang prefix-cache idiom,
and the serving-side twin of FAME's persisted-memory context reuse (agent
turns re-send the same conversation prefix; PAPER.md §3.3).

Ownership / lifetime rules:

* The tree owns the pages of its nodes; the page allocator's free list owns
  everything else. A page is never in both places.
* ``match`` pins the deepest matched node (refcount) for the lifetime of the
  request; ``release`` unpins. Eviction removes only *leaf* nodes with
  refcount 0, so a pinned node's ancestors (which the request's block table
  references) can never be evicted — they have children.
* ``insert`` adopts pages from a finished request, one node per complete
  block. Blocks already present keep the incumbent page and the duplicate is
  handed back to the caller to free (two identical prompts racing through
  prefill).
* Eviction is LRU by a logical clock bumped on every match/insert touch.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class RadixNode:
    page: int                                    # pool page holding this block
    parent: Optional["RadixNode"]
    key: Optional[Tuple[int, ...]]               # edge label (page_size tokens)
    children: Dict[Tuple[int, ...], "RadixNode"] = dataclasses.field(
        default_factory=dict)
    ref: int = 0                                 # requests pinned at this node
    last: int = 0                                # logical clock of last touch


class RadixTree:
    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.root = RadixNode(page=-1, parent=None, key=None)
        self._tick = 0
        self.evicted_pages = 0          # engine.stats() reads this; token
                                        # hit/miss accounting lives in the
                                        # engine (it caps the usable match)

    # ---- internals ---------------------------------------------------------
    def _touch(self, node: RadixNode):
        self._tick += 1
        while node is not None and node.key is not None:
            node.last = self._tick
            node = node.parent
        self.root.last = self._tick

    def _blocks(self, tokens) -> List[Tuple[int, ...]]:
        ps = self.page_size
        n = len(tokens) // ps
        return [tuple(tokens[i * ps:(i + 1) * ps]) for i in range(n)]

    def _iter_nodes(self):
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    # ---- queries -----------------------------------------------------------
    def match(self, tokens) -> Tuple[List[int], RadixNode]:
        """Longest cached prefix of ``tokens`` in whole pages.

        Returns (page chain, deepest matched node) and pins the node — call
        ``release`` when the request finishes. The caller is responsible for
        capping the usable prefix (an engine always recomputes at least the
        last prompt token to get first-token logits).
        """
        node, pages = self.root, []
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            node = child
            pages.append(node.page)
        node.ref += 1
        self._touch(node)
        return pages, node

    def release(self, node: RadixNode):
        assert node.ref > 0, "release without matching match()"
        node.ref -= 1

    def insert(self, tokens, pages: List[int]) -> List[int]:
        """Adopt ``pages`` (one per complete block of ``tokens``) into the
        trie. Returns the duplicate pages NOT adopted (already-present
        blocks) — the caller must free them."""
        blocks = self._blocks(tokens)
        assert len(pages) >= len(blocks), (len(pages), len(blocks))
        node, rejected = self.root, []
        for key, page in zip(blocks, pages):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(page=page, parent=node, key=key)
                node.children[key] = child
            elif child.page != page:
                rejected.append(page)
            node = child
        self._touch(node)
        return rejected

    # ---- eviction ----------------------------------------------------------
    def evict(self, n_pages: int) -> List[int]:
        """Free up to ``n_pages`` pages by removing LRU unpinned leaves.
        Returns the freed pages (caller returns them to the allocator).

        One tree walk collects the evictable frontier into a min-heap by
        ``last``; a parent enters the heap the moment its final child is
        removed, so bulk eviction is O(N + k log N), not O(N·k).
        """
        heap = [(n.last, id(n), n) for n in self._iter_nodes()
                if not n.children and n.ref == 0]
        heapq.heapify(heap)
        freed: List[int] = []
        while heap and len(freed) < n_pages:
            _, _, node = heapq.heappop(heap)
            del node.parent.children[node.key]
            freed.append(node.page)
            parent = node.parent
            if (parent.key is not None and not parent.children
                    and parent.ref == 0):
                heapq.heappush(heap, (parent.last, id(parent), parent))
        self.evicted_pages += len(freed)
        return freed

    # ---- introspection -----------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._iter_nodes())

    @property
    def cached_pages(self) -> List[int]:
        return [n.page for n in self._iter_nodes()]

    def check_invariants(self):
        """Structural invariants (property tests): refcounts non-negative,
        page ids unique, parent/child links consistent."""
        seen = set()
        for node in self._iter_nodes():
            assert node.ref >= 0, "negative refcount"
            assert node.page >= 0, "tree node without a page"
            assert node.page not in seen, f"page {node.page} owned twice"
            seen.add(node.page)
            assert node.parent.children[node.key] is node
            assert len(node.key) == self.page_size
        return seen
