"""Radix (token-trie) index over shared KV-cache pages and state snapshots.

Maps token-id prefixes of past requests to chains of KV pages in the paged
pool (serving/kvpool.py), at page granularity: each trie edge is one
``page_size``-token block, each node owns exactly one page holding that
block's K/V. A new request walks the trie with its prompt and reuses every
matched page without re-prefilling it — the vLLM / SGLang prefix-cache idiom,
and the serving-side twin of FAME's persisted-memory context reuse (agent
turns re-send the same conversation prefix; PAPER.md §3.3).

Stateful archs (recurrent / conv / xLSTM / ring-KV — no shareable pages)
index *recurrent-state snapshots* instead: a node may own one slot of the
pooled snapshot arena (serving/kvpool.SnapshotArena) holding the model's
fixed-size state after prefilling exactly up to that node's prefix boundary.
A radix hit then restores the nearest ancestor snapshot into the slot and
prefills only the suffix — the same sublinear-prefix property, O(1) storage
per boundary instead of O(tokens). One tree is used in one mode: every node
carries a page (attention-paged) or some nodes carry a snap (snapshot mode);
never both.

Ownership / lifetime rules:

* The tree owns the pages and snapshot slots of its nodes; the page
  allocator / snapshot arena free lists own everything else. A resource is
  never in both places.
* ``match`` pins the deepest matched node (refcount) for the lifetime of the
  request; ``release`` unpins. Eviction removes only *leaf* nodes with
  refcount 0, so a pinned node's ancestors (which the request's block table
  references) can never be evicted — they have children.
* ``insert`` adopts pages from a finished request, one node per complete
  block; ``insert_snaps`` adopts snapshot slots at chosen boundaries.
  Blocks already present keep the incumbent page/snap and the duplicate is
  handed back to the caller to free (two identical prompts racing through
  prefill).
* Eviction is LRU by a logical clock bumped on every match/insert touch.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class RadixNode:
    page: int                                    # pool page holding this block
                                                 # (-1: snapshot-mode node)
    parent: Optional["RadixNode"]
    key: Optional[Tuple[int, ...]]               # edge label (page_size tokens)
    children: Dict[Tuple[int, ...], "RadixNode"] = dataclasses.field(
        default_factory=dict)
    ref: int = 0                                 # requests pinned at this node
    last: int = 0                                # logical clock of last touch
    snap: int = -1                               # snapshot-arena slot holding
                                                 # the state at this boundary
                                                 # (-1: none)


class RadixTree:
    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.root = RadixNode(page=-1, parent=None, key=None)
        self._tick = 0
        self.evicted_pages = 0          # engine.stats() reads these; token
        self.evicted_snaps = 0          # hit/miss accounting lives in the
                                        # engine (it caps the usable match)

    # ---- internals ---------------------------------------------------------
    def _touch(self, node: RadixNode):
        self._tick += 1
        while node is not None and node.key is not None:
            node.last = self._tick
            node = node.parent
        self.root.last = self._tick

    def _blocks(self, tokens) -> List[Tuple[int, ...]]:
        ps = self.page_size
        n = len(tokens) // ps
        return [tuple(tokens[i * ps:(i + 1) * ps]) for i in range(n)]

    def _iter_nodes(self):
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    # ---- queries -----------------------------------------------------------
    def match(self, tokens) -> Tuple[List[int], RadixNode]:
        """Longest cached prefix of ``tokens`` in whole pages.

        Returns (page chain, deepest matched node) and pins the node — call
        ``release`` when the request finishes. The caller is responsible for
        capping the usable prefix (an engine always recomputes at least the
        last prompt token to get first-token logits).
        """
        node, pages = self.root, []
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            node = child
            pages.append(node.page)
        node.ref += 1
        self._touch(node)
        return pages, node

    def release(self, node: RadixNode):
        assert node.ref > 0, "release without matching match()"
        node.ref -= 1

    def nearest_snapshot(self, node: RadixNode) -> Tuple[int, int]:
        """Deepest snapshot at or above ``node``: (snap id, depth in blocks),
        or (-1, 0) when no ancestor boundary has a live snapshot. Restoring
        it and prefilling the remaining suffix reproduces the state a full
        prefill of the matched prefix would build."""
        depth = 0
        n = node
        while n.key is not None:
            depth += 1
            n = n.parent
        while node.key is not None:
            if node.snap >= 0:
                return node.snap, depth
            node, depth = node.parent, depth - 1
        return -1, 0

    def insert(self, tokens, pages: List[int]) -> List[int]:
        """Adopt ``pages`` (one per complete block of ``tokens``) into the
        trie. Returns the duplicate pages NOT adopted (already-present
        blocks) — the caller must free them."""
        blocks = self._blocks(tokens)
        assert len(pages) >= len(blocks), (len(pages), len(blocks))
        node, rejected = self.root, []
        for key, page in zip(blocks, pages):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(page=page, parent=node, key=key)
                node.children[key] = child
            elif child.page != page:
                rejected.append(page)
            node = child
        self._touch(node)
        return rejected

    def insert_snaps(self, tokens, snaps: Dict[int, int]) -> List[int]:
        """Adopt snapshot slots into the trie (snapshot-mode trees: nodes
        carry no pages). ``snaps`` maps a depth in blocks (1-based: the
        boundary after that many complete blocks of ``tokens``) to the
        arena slot holding the state at that boundary. Missing path nodes
        are created with ``page=-1``. Returns the snap ids NOT adopted
        (boundary already has a snapshot, or depth out of range) — the
        caller must free them back to the arena."""
        blocks = self._blocks(tokens)
        node, rejected = self.root, []
        for depth, key in enumerate(blocks, start=1):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(page=-1, parent=node, key=key)
                node.children[key] = child
            sid = snaps.get(depth, -1)
            if sid >= 0:
                if child.snap < 0:
                    child.snap = sid
                else:
                    rejected.append(sid)
            node = child
        rejected.extend(sid for depth, sid in snaps.items()
                        if sid >= 0 and not (1 <= depth <= len(blocks)))
        self._touch(node)
        return rejected

    # ---- eviction ----------------------------------------------------------
    def _evict_leaves(self, done) -> Tuple[List[int], List[int]]:
        """Remove LRU unpinned leaves until ``done(pages, snaps)`` or none
        remain. Returns the freed (pages, snaps) for the caller to return to
        the allocator / arena.

        One tree walk collects the evictable frontier into a min-heap by
        ``last``; a parent enters the heap the moment its final child is
        removed, so bulk eviction is O(N + k log N), not O(N·k).
        """
        heap = [(n.last, id(n), n) for n in self._iter_nodes()
                if not n.children and n.ref == 0]
        heapq.heapify(heap)
        pages: List[int] = []
        snaps: List[int] = []
        while heap and not done(pages, snaps):
            _, _, node = heapq.heappop(heap)
            del node.parent.children[node.key]
            if node.page >= 0:
                pages.append(node.page)
            if node.snap >= 0:
                snaps.append(node.snap)
            parent = node.parent
            if (parent.key is not None and not parent.children
                    and parent.ref == 0):
                heapq.heappush(heap, (parent.last, id(parent), parent))
        self.evicted_pages += len(pages)
        self.evicted_snaps += len(snaps)
        return pages, snaps

    def evict(self, n_pages: int) -> List[int]:
        """Free up to ``n_pages`` pages by removing LRU unpinned leaves."""
        return self._evict_leaves(lambda p, s: len(p) >= n_pages)[0]

    def evict_snaps(self, n_snaps: int) -> List[int]:
        """Free up to ``n_snaps`` snapshot slots (snapshot-mode trees).
        Snap-less leaves on the LRU frontier are removed along the way —
        they only exist as path to deeper snapshots."""
        return self._evict_leaves(lambda p, s: len(s) >= n_snaps)[1]

    # ---- introspection -----------------------------------------------------
    def keyspace_digest(self) -> frozenset:
        """Cheap summary of which prompt keyspaces this tree caches: the
        hashes of the FIRST-block edge labels (the root's children — one per
        distinct leading ``page_size``-token block ever adopted). A fleet
        router compares a new prompt's first block against every replica's
        digest to land it where shared prefix pages/snapshots already live.
        O(#distinct first blocks), no tree walk; hashes (not token tuples)
        so the exported set stays small and opaque."""
        return frozenset(hash(k) for k in self.root.children)

    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._iter_nodes())

    @property
    def cached_pages(self) -> List[int]:
        return [n.page for n in self._iter_nodes() if n.page >= 0]

    @property
    def cached_snaps(self) -> List[int]:
        return [n.snap for n in self._iter_nodes() if n.snap >= 0]

    def check_invariants(self, snapshots: bool = False):
        """Structural invariants (property tests): refcounts non-negative,
        page/snap ids unique, parent/child links consistent. Returns the set
        of owned pages (``snapshots=False``) or snapshot slots."""
        seen = set()
        snaps = set()
        for node in self._iter_nodes():
            assert node.ref >= 0, "negative refcount"
            if snapshots:
                assert node.page < 0, "snapshot-mode node owns a page"
            else:
                assert node.page >= 0, "tree node without a page"
                assert node.page not in seen, f"page {node.page} owned twice"
                seen.add(node.page)
            if node.snap >= 0:
                assert node.snap not in snaps, f"snap {node.snap} owned twice"
                snaps.add(node.snap)
            assert node.parent.children[node.key] is node
            assert len(node.key) == self.page_size
        return snaps if snapshots else seen
