"""Session-oriented continuous-batching serving frontend.

``LLMServer`` is the public face of the serving stack (the redesign of the
blocking ``ServingEngine.submit()/run_until_drained()`` loop): requests are
submitted as non-blocking **handles**, conversations live in **sessions**
whose end-of-generation state is retained for the next turn, output streams
incrementally off the engine's per-chunk host sync, and any handle can be
**cancelled** mid-flight.

    server = LLMServer(cfg, num_slots=4, capacity=512,
                       engine_cfg=EngineConfig(cache_mode="paged"))
    sess = server.open_session()
    h = sess.submit(conversation_text, SamplingParams(max_new_tokens=64))
    for piece in h.stream():          # incremental detokenized text
        print(piece, end="")
    text = h.result()                 # or just block for the full output

Concurrency model: the server is cooperative, not threaded. ``submit`` only
queues; ``step()`` runs ONE engine iteration (admission + one decode chunk /
verify pass for every live slot) and distributes freshly decoded text to the
live handles. ``handle.stream()`` / ``handle.result()`` pump ``step()``
until their request completes — so N handles submitted before any of them
is drained co-batch inside the same engine steps, which is exactly how N
concurrent agent workflows share one model (``stats()
["active_slots_per_step"]`` measures it; benchmarks/session_bench.py gates
on it).

Multi-turn reuse: a ``Session`` tracks its conversation; when turn N+1's
prompt extends turn N's text, the engine restores the retained tail state
(partial KV tail page on full-attention archs, end-of-generation state
snapshot on stateful archs — both at exact, non-block-aligned boundaries)
and prefills only the new message. See serving/scheduler.py for the
mechanics and docs/serving.md for the full reference.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterator, List, Optional, Union

from repro.serving.faults import (CorruptionError, DeadLetterError,
                                  DeadlineExceeded, FaultError, FaultInjector,
                                  RequestFault, RequestStatus, RetryPolicy,
                                  TransientFault)
from repro.serving.journal import SessionJournal
from repro.serving.scheduler import (EngineConfig, Request, SamplingParams,
                                     Scheduler)

__all__ = ["LLMServer", "Session", "Handle", "SamplingParams", "EngineConfig",
           "RequestStatus", "RetryPolicy", "FaultInjector", "SessionJournal",
           "FaultError", "TransientFault", "RequestFault", "CorruptionError",
           "DeadlineExceeded", "DeadLetterError"]


def _utf8_holdback(ids: List[int]) -> int:
    """How many trailing tokens to withhold from an incremental decode:
    raw byte tokens (< 0x100) forming an incomplete UTF-8 sequence decode
    to replacement characters on their own, so the stream holds them back
    until the sequence completes (at most 3 tokens). Merge and special
    tokens are self-contained and never held."""
    n = 0
    i = len(ids) - 1
    while i >= 0 and n < 3 and 0x80 <= ids[i] <= 0xBF:   # continuation bytes
        i -= 1
        n += 1
    if i >= 0 and 0xC2 <= ids[i] <= 0xF4:                # lead byte
        need = 2 if ids[i] < 0xE0 else 3 if ids[i] < 0xF0 else 4
        if 1 + n < need:
            return n + 1                                 # lead + partial tail
    return 0


class Handle:
    """One in-flight (or finished) request.

    ``status()`` is a ``RequestStatus`` (serving/faults.py): ``QUEUED`` or
    ``RUNNING`` while live, then exactly one terminal state — ``COMPLETED``,
    ``CANCELLED``, ``TIMED_OUT`` (deadline elapsed), or ``FAILED``
    (dead-lettered after a non-transient fault; ``exception()`` has the
    error). ``text`` is everything streamed so far; after completion it
    equals ``result()`` (stop-trimmed).
    """

    def __init__(self, server: "LLMServer", request: Request):
        self._server = server
        self.request = request
        self.text = ""
        self._pending: "collections.deque[str]" = collections.deque()
        self._sent = 0                  # generated tokens already delivered

    def status(self) -> RequestStatus:
        return RequestStatus(self.request.status)

    def exception(self) -> Optional[BaseException]:
        """The error that terminated this request (``FAILED`` /
        ``TIMED_OUT``), else None."""
        return self.request.error

    @property
    def done(self) -> bool:
        return self.request.finished

    def stream(self) -> Iterator[str]:
        """Yield detokenized text increments as they decode (one per engine
        chunk that emitted new text for this request). Pumps the server
        between yields, so concurrently submitted handles keep decoding —
        their increments buffer in their own handles."""
        while True:
            while self._pending:
                yield self._pending.popleft()
            if self.request.finished:
                return
            self._server.step()

    def result(self) -> str:
        """Block (cooperatively) until the request finishes; returns the
        full output text. A cancelled or timed-out handle returns its
        partial output (the deadline is a budget, not an error; the cause
        stays on ``exception()``). A FAILED handle re-raises its error."""
        for _ in self.stream():
            pass
        if self.request.status == "failed":
            raise self.request.error
        return self.request.output_text

    def cancel(self) -> bool:
        return self._server.cancel(self)

    # server-side delivery
    def _push(self, piece: str):
        self._pending.append(piece)
        self.text += piece


class Session:
    """One multi-turn conversation on an ``LLMServer``.

    Submit each turn's prompt as the FULL conversation text (what an agent
    frontend naturally re-sends); when it extends the previous turn's
    ``text`` (prompt + generated output), the engine reuses the retained
    end-of-generation state and prefills only the new part. One turn may be
    in flight at a time — turn N+1's prompt depends on turn N's output.
    """

    def __init__(self, server: "LLMServer", sid: int):
        self._server = server
        self.sid = sid
        self.closed = False

    @property
    def text(self) -> str:
        """Conversation so far: last submitted prompt + its generated
        output. Build the next turn's prompt by appending to this."""
        sess = self._server.engine._sessions.get(self.sid)
        return sess.text if sess is not None else ""

    @property
    def turns(self) -> int:
        sess = self._server.engine._sessions.get(self.sid)
        return sess.turns if sess is not None else 0

    @property
    def busy(self) -> bool:
        """True while a turn of this session is still queued or running."""
        sess = self._server.engine._sessions.get(self.sid)
        return (sess is not None and sess.live is not None
                and not sess.live.finished)

    def submit(self, prompt: str,
               params: Optional[SamplingParams] = None) -> Handle:
        if self.closed:
            raise RuntimeError(f"session {self.sid} is closed")
        return self._server.submit(prompt, params, session=self.sid)

    def close(self):
        """Release the session's retained tail state (pages / snapshot /
        radix pins); cancels a still-running turn."""
        if not self.closed:
            self._server.engine.close_session(self.sid)
            self.closed = True


class LLMServer:
    """Session-oriented continuous-batching server over the scheduler."""

    def __init__(self, cfg, *, num_slots: int = 4, capacity: int = 512,
                 params=None, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None,
                 retry: Optional[RetryPolicy] = None,
                 default_deadline_s: Optional[float] = None,
                 injector: Optional[FaultInjector] = None,
                 journal_path: Optional[str] = None,
                 watchdog_s: Optional[float] = None):
        self.engine = Scheduler(cfg, num_slots=num_slots, capacity=capacity,
                                params=params, seed=seed,
                                engine_cfg=engine_cfg, retry=retry,
                                default_deadline_s=default_deadline_s,
                                injector=injector, journal_path=journal_path,
                                watchdog_s=watchdog_s)
        self._handles: "dict[int, Handle]" = {}       # rid -> live handle

    # convenient passthroughs
    @property
    def params(self):
        return self.engine.params

    @property
    def capacity(self) -> int:
        return self.engine.capacity

    def stats(self) -> dict:
        return self.engine.stats()

    @property
    def journal(self) -> SessionJournal:
        """The crash-safe session journal (serving/journal.py). Pass
        ``journal_path=`` at construction to spill it to JSON after every
        turn; feed it (or its path) to a fresh server's
        ``restore_sessions()`` after a crash."""
        return self.engine.journal

    # ---- sessions / submission ---------------------------------------------
    def open_session(self) -> Session:
        return Session(self, self.engine.open_session())

    def restore_sessions(self, journal: Union[SessionJournal, str]
                         ) -> Dict[int, Session]:
        """Rebuild every session in ``journal`` (a ``SessionJournal`` or a
        path to a spilled JSON file) on this server: each journaled token
        stream is replayed through the normal prefill path, re-creating the
        retained tail state at its exact end-of-generation boundary — the
        next turn's greedy output is bit-identical to an uninterrupted
        server. Returns {old session id -> new live Session}."""
        if isinstance(journal, str):
            journal = SessionJournal.load(journal)
        restored: Dict[int, Session] = {}
        for entry in journal.entries():
            sid = self.engine.restore_session(entry)
            restored[entry.sid] = Session(self, sid)
        return restored

    def submit(self, prompt: str, params: Optional[SamplingParams] = None,
               *, session: Optional[int] = None,
               token_ids: Optional[List[int]] = None) -> Handle:
        """Queue a request (non-blocking) and return its handle. Nothing
        runs until someone pumps ``step()`` — usually via
        ``handle.stream()`` / ``handle.result()`` — so handles submitted
        together co-batch."""
        req = self.engine.enqueue(prompt, params, session=session,
                                  token_ids=token_ids)
        h = Handle(self, req)
        self._handles[req.rid] = h
        return h

    def cancel(self, handle: Handle) -> bool:
        """Cancel a queued or running handle: its slot, private KV pages,
        and radix pins are released immediately; the handle keeps whatever
        partial text was already decoded."""
        ok = self.engine.cancel(handle.request)
        self._deliver()
        return ok

    # ---- the cooperative pump ----------------------------------------------
    def step(self) -> bool:
        """One engine iteration for ALL live requests, then deliver newly
        decoded text to their handles. Returns True while there is work."""
        progressed = self.engine.step()
        self._deliver()
        return progressed or bool(self.engine._queue)

    def run_until_idle(self):
        """Drain everything currently queued or running."""
        while self.step():
            pass

    def _deliver(self):
        """Distribute newly decoded (stop-trimmed) text to live handles —
        the streaming counterpart of the engine's one-host-sync-per-chunk
        contract: at most one delivery per handle per chunk.

        Increments are decoded from the NEW tokens only (O(chunk), not
        O(output so far)), holding back a trailing incomplete UTF-8
        sequence so a multi-byte character split across chunk syncs is
        delivered whole once its last byte lands — the concatenated stream
        always equals ``result()``."""
        eng = self.engine
        by_rid = {s.request.rid: s for s in eng.slots if s.request is not None}
        for rid, h in list(self._handles.items()):
            req = h.request
            if req.finished:
                ids = req.output_ids or []
                tail = eng.tokenizer.decode(ids[h._sent:])
                h._sent = len(ids)
                if tail:
                    h._push(tail)
                    eng._stream_chunks += 1
                del self._handles[rid]
                continue
            slot = by_rid.get(rid)
            if slot is None:
                continue
            avail = len(slot.generated) - _utf8_holdback(slot.generated)
            if avail > h._sent:
                piece = eng.tokenizer.decode(slot.generated[h._sent:avail])
                h._sent = avail
                if piece:                       # all-specials chunks skip
                    h._push(piece)
                    eng._stream_chunks += 1
