"""Session-oriented continuous-batching serving frontend.

``LLMServer`` is the public face of the serving stack (the redesign of the
blocking ``ServingEngine.submit()/run_until_drained()`` loop): requests are
submitted as non-blocking **handles**, conversations live in **sessions**
whose end-of-generation state is retained for the next turn, output streams
incrementally off the engine's per-chunk host sync, and any handle can be
**cancelled** mid-flight.

    server = LLMServer(cfg, num_slots=4, capacity=512,
                       engine_cfg=EngineConfig(cache_mode="paged"))
    sess = server.open_session()
    h = sess.submit(conversation_text, SamplingParams(max_new_tokens=64))
    for piece in h.stream():          # incremental detokenized text
        print(piece, end="")
    text = h.result()                 # or just block for the full output

Concurrency model: by default the server is cooperative. ``submit`` only
queues; ``step()`` runs ONE engine iteration (admission + one decode chunk /
verify pass for every live slot) and distributes freshly decoded text to the
live handles. ``handle.stream()`` / ``handle.result()`` pump ``step()``
until their request completes — so N handles submitted before any of them
is drained co-batch inside the same engine steps, which is exactly how N
concurrent agent workflows share one model (``stats()
["active_slots_per_step"]`` measures it; benchmarks/session_bench.py gates
on it).

Always-on mode: ``LLMServer(cfg, pump=True)`` starts a background pump
(serving/pump.py) — a daemon thread that owns the engine loop. ``submit`` /
``cancel`` / session calls become thread-safe (they route through the
pump's command queue and run on the pump thread), handle streams block on
the pump's progress signal instead of stepping, and a wedged pump surfaces
as a typed ``PumpStalledError`` to whoever is waiting. Shut it down with
``server.close()`` or a ``with LLMServer(...) as server:`` block.

Overload control: pass ``overload=OverloadPolicy(...)`` to bound the
admission queue (typed ``OverloadError`` to submitters), shed queued
requests that cannot meet their deadline (terminal status ``"shed"``), and
preempt running low-priority decodes under admission pressure — preempted
requests resume bit-identically. See scheduler.OverloadPolicy.

Multi-turn reuse: a ``Session`` tracks its conversation; when turn N+1's
prompt extends turn N's text, the engine restores the retained tail state
(partial KV tail page on full-attention archs, end-of-generation state
snapshot on stateful archs — both at exact, non-block-aligned boundaries)
and prefills only the new message. See serving/scheduler.py for the
mechanics and docs/serving.md for the full reference.
"""
from __future__ import annotations

import collections
import enum
from typing import Dict, Iterator, List, Optional, Union

from repro.serving.faults import (CorruptionError, DeadLetterError,
                                  DeadlineExceeded, FaultError, FaultInjector,
                                  OverloadError, PumpStalledError,
                                  RequestFault, RequestStatus, RetryPolicy,
                                  ShedError, TransientFault)
from repro.serving.journal import SessionJournal
from repro.serving.pump import BackgroundPump, PumpConfig
from repro.serving.scheduler import (EngineConfig, OverloadPolicy, Request,
                                     SamplingParams, Scheduler)

__all__ = ["LLMServer", "Session", "Handle", "StepOutcome", "SamplingParams",
           "EngineConfig", "OverloadPolicy", "PumpConfig", "RequestStatus",
           "RetryPolicy", "FaultInjector", "SessionJournal", "FaultError",
           "TransientFault", "RequestFault", "CorruptionError",
           "DeadlineExceeded", "DeadLetterError", "OverloadError",
           "ShedError", "PumpStalledError"]


class StepOutcome(enum.Enum):
    """Tri-state result of ``LLMServer.step()``.

    PROGRESSED — the engine ran work (a decode chunk / verify / admission).
    WAITING    — nothing could advance, but queued work exists (every queued
                 request is in admission backoff; the engine already slept
                 toward the earliest retry, so a ``while server.step():``
                 loop cannot busy-spin).
    IDLE       — no queued and no running work.

    Truthiness preserves the old ``bool`` contract: IDLE is falsy,
    everything else truthy.
    """
    PROGRESSED = "progressed"
    WAITING = "waiting"
    IDLE = "idle"

    def __bool__(self) -> bool:
        return self is not StepOutcome.IDLE


def _utf8_holdback(ids: List[int]) -> int:
    """How many trailing tokens to withhold from an incremental decode:
    raw byte tokens (< 0x100) forming an incomplete UTF-8 sequence decode
    to replacement characters on their own, so the stream holds them back
    until the sequence completes (at most 3 tokens). Merge and special
    tokens are self-contained and never held."""
    n = 0
    i = len(ids) - 1
    while i >= 0 and n < 3 and 0x80 <= ids[i] <= 0xBF:   # continuation bytes
        i -= 1
        n += 1
    if i >= 0 and 0xC2 <= ids[i] <= 0xF4:                # lead byte
        need = 2 if ids[i] < 0xE0 else 3 if ids[i] < 0xF0 else 4
        if 1 + n < need:
            return n + 1                                 # lead + partial tail
    return 0


class Handle:
    """One in-flight (or finished) request.

    ``status()`` is a ``RequestStatus`` (serving/faults.py): ``QUEUED`` or
    ``RUNNING`` while live, then exactly one terminal state — ``COMPLETED``,
    ``CANCELLED``, ``TIMED_OUT`` (deadline elapsed), ``FAILED``
    (dead-lettered after a non-transient fault; ``exception()`` has the
    error), or ``SHED`` (dropped by the overload policy before running).
    ``text`` is everything streamed so far; after completion it equals
    ``result()`` (stop-trimmed). A preempted request transiently reports
    ``QUEUED`` again until its bit-identical resumption.
    """

    def __init__(self, server: "LLMServer", request: Request):
        self._server = server
        self.request = request
        self.text = ""
        self._pending: "collections.deque[str]" = collections.deque()
        self._sent = 0                  # generated tokens already delivered

    def status(self) -> RequestStatus:
        return RequestStatus(self.request.status)

    def exception(self) -> Optional[BaseException]:
        """The error that terminated this request (``FAILED`` /
        ``TIMED_OUT``), else None."""
        return self.request.error

    @property
    def done(self) -> bool:
        return self.request.finished

    def stream(self) -> Iterator[str]:
        """Yield detokenized text increments as they decode (one per engine
        chunk that emitted new text for this request). Cooperative servers
        pump ``step()`` between yields, so concurrently submitted handles
        keep decoding — their increments buffer in their own handles. With
        a background pump this blocks on the pump's progress signal instead
        (and raises ``PumpStalledError`` if the pump wedges or dies)."""
        while True:
            while self._pending:
                yield self._pending.popleft()
            if self.request.finished:
                return
            self._server._advance()

    def wait(self) -> "Handle":
        """Block until the request reaches a terminal status (without
        consuming the stream — increments stay buffered); returns self."""
        while not self.request.finished:
            self._server._advance()
        return self

    def result(self) -> str:
        """Block (cooperatively, or on the pump) until the request
        finishes; returns the full output text. A cancelled or timed-out
        handle returns its partial output (the deadline is a budget, not an
        error; the cause stays on ``exception()``). A FAILED or SHED handle
        re-raises its error."""
        for _ in self.stream():
            pass
        if self.request.status in ("failed", "shed"):
            raise self.request.error
        return self.request.output_text

    def cancel(self) -> bool:
        return self._server.cancel(self)

    # server-side delivery
    def _push(self, piece: str):
        self._pending.append(piece)
        self.text += piece


class Session:
    """One multi-turn conversation on an ``LLMServer``.

    Submit each turn's prompt as the FULL conversation text (what an agent
    frontend naturally re-sends); when it extends the previous turn's
    ``text`` (prompt + generated output), the engine reuses the retained
    end-of-generation state and prefills only the new part. One turn may be
    in flight at a time — turn N+1's prompt depends on turn N's output.
    """

    def __init__(self, server: "LLMServer", sid: int):
        self._server = server
        self.sid = sid
        self.closed = False

    @property
    def text(self) -> str:
        """Conversation so far: last submitted prompt + its generated
        output. Build the next turn's prompt by appending to this."""
        sess = self._server.engine._sessions.get(self.sid)
        return sess.text if sess is not None else ""

    @property
    def turns(self) -> int:
        sess = self._server.engine._sessions.get(self.sid)
        return sess.turns if sess is not None else 0

    @property
    def busy(self) -> bool:
        """True while a turn of this session is still queued or running."""
        sess = self._server.engine._sessions.get(self.sid)
        return (sess is not None and sess.live is not None
                and not sess.live.finished)

    def submit(self, prompt: str,
               params: Optional[SamplingParams] = None) -> Handle:
        if self.closed:
            raise RuntimeError(f"session {self.sid} is closed")
        return self._server.submit(prompt, params, session=self.sid)

    def close(self):
        """Release the session's retained tail state (pages / snapshot /
        radix pins); cancels a still-running turn. Thread-safe under a
        background pump (routed to the pump thread)."""
        if not self.closed:
            self._server._call(
                lambda: self._server.engine.close_session(self.sid))
            self.closed = True


class LLMServer:
    """Session-oriented continuous-batching server over the scheduler.

    ``pump=True`` (or a ``PumpConfig``) starts the background pump: the
    engine loop runs on a daemon thread, the submit/cancel/session surface
    becomes thread-safe, and the server must be shut down via ``close()``
    or a ``with`` block. ``overload=OverloadPolicy(...)`` enables bounded
    admission, load shedding, the dispatch circuit breaker, and priority
    preemption (see scheduler.py). ``engine_cfg=EngineConfig(mesh=...)``
    shards the jit programs, cache rows, page pool and snapshot arena over
    a JAX device mesh with greedy outputs bit-identical to single-device
    (docs/serving.md, "Sharded serving").
    """

    def __init__(self, cfg, *, num_slots: int = 4, capacity: int = 512,
                 params=None, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None,
                 retry: Optional[RetryPolicy] = None,
                 default_deadline_s: Optional[float] = None,
                 injector: Optional[FaultInjector] = None,
                 journal_path: Optional[str] = None,
                 watchdog_s: Optional[float] = None,
                 overload: Optional[OverloadPolicy] = None,
                 pump: Union[bool, PumpConfig, None] = None):
        self.engine = Scheduler(cfg, num_slots=num_slots, capacity=capacity,
                                params=params, seed=seed,
                                engine_cfg=engine_cfg, retry=retry,
                                default_deadline_s=default_deadline_s,
                                injector=injector, journal_path=journal_path,
                                watchdog_s=watchdog_s, overload=overload)
        self._handles: "dict[int, Handle]" = {}       # rid -> live handle
        self._pump: Optional[BackgroundPump] = None
        if pump:
            self._pump = BackgroundPump(
                self, pump if isinstance(pump, PumpConfig) else None)
            self._pump.start()

    # ---- pump plumbing -----------------------------------------------------
    @property
    def pumping(self) -> bool:
        """True while the background pump owns the engine loop."""
        return self._pump is not None and self._pump.alive

    def _call(self, fn):
        """Run ``fn`` on whichever thread owns the engine: inline when
        cooperative (or already on the pump thread), else through the
        pump's command queue. A dead pump (crashed) no longer owns the
        engine, so post-mortem reads run inline."""
        if self._pump is not None and self._pump.alive:
            return self._pump.call(fn)
        return fn()

    def _advance(self):
        """Make progress observable to a blocked waiter: one cooperative
        ``step()``, or a bounded wait on the pump's progress signal."""
        if self._pump is not None:
            self._pump.wait_progress()
        else:
            self.step()

    def close(self, drain: bool = False):
        """Shut the server down. With a pump: stop it — outstanding
        requests are cancelled on the pump thread first (``drain=True``
        finishes them instead), so nothing is stranded. Cooperative servers
        just cancel (or drain) outstanding handles."""
        if self._pump is not None:
            self._pump.close(drain=drain)
            self._pump = None
            return
        if drain:
            self.run_until_idle()
        for h in list(self._handles.values()):
            if not h.request.finished:
                self.engine.cancel(h.request)
        self._deliver()

    def __enter__(self) -> "LLMServer":
        return self

    def __exit__(self, *exc_info):
        self.close()

    # convenient passthroughs
    @property
    def params(self):
        return self.engine.params

    @property
    def mesh(self):
        """The serving device mesh (``EngineConfig(mesh=...)``; defaults to
        the degenerate 1×1 host mesh — single-device, unsharded)."""
        return self.engine.mesh

    @property
    def capacity(self) -> int:
        return self.engine.capacity

    @property
    def tokenizer(self):
        """The engine's tokenizer (stateless; safe to share across
        threads). Fleet fronts expose the same property, so callers that
        only encode/decode text — e.g. fame/bindings.py's delta billing —
        need not reach into ``server.engine``."""
        return self.engine.tokenizer

    def radix_digest(self) -> frozenset:
        """The engine's first-block radix keyspace digest (empty in dense
        mode), read on the engine-owning thread. serving/fleet.py routes
        prefix-affine placements with it."""
        return self._call(self.engine.radix_digest)

    def load_score(self) -> float:
        """Racy (lock-free) load heuristic for fleet routing — see
        Scheduler.load_score. Deliberately NOT routed through the pump: a
        router comparing N replicas must not pay N command round-trips per
        placement."""
        return self.engine.load_score()

    def stats(self) -> dict:
        out = self._call(self.engine.stats)
        if self._pump is not None:
            out.update({
                "pump_alive": self._pump.alive,
                "pump_steps": self._pump.steps,
                "pump_stall_notices": self._pump.stall_notices,
            })
        return out

    @property
    def journal(self) -> SessionJournal:
        """The crash-safe session journal (serving/journal.py). Pass
        ``journal_path=`` at construction to spill it to JSON after every
        turn; feed it (or its path) to a fresh server's
        ``restore_sessions()`` after a crash."""
        return self.engine.journal

    # ---- sessions / submission ---------------------------------------------
    def open_session(self) -> Session:
        return Session(self, self._call(self.engine.open_session))

    def restore_sessions(self, journal: Union[SessionJournal, str]
                         ) -> Dict[int, Session]:
        """Rebuild every session in ``journal`` (a ``SessionJournal`` or a
        path to a spilled JSON file) on this server: each journaled token
        stream is replayed through the normal prefill path, re-creating the
        retained tail state at its exact end-of-generation boundary — the
        next turn's greedy output is bit-identical to an uninterrupted
        server. Returns {old session id -> new live Session}."""
        if isinstance(journal, str):
            journal = SessionJournal.load(journal)

        def _restore():
            restored: Dict[int, Session] = {}
            for entry in journal.entries():
                sid = self.engine.restore_session(entry)
                restored[entry.sid] = Session(self, sid)
            return restored
        return self._call(_restore)

    def submit(self, prompt: str, params: Optional[SamplingParams] = None,
               *, session: Optional[int] = None,
               token_ids: Optional[List[int]] = None) -> Handle:
        """Queue a request (non-blocking) and return its handle. On a
        cooperative server nothing runs until someone pumps ``step()`` —
        usually via ``handle.stream()`` / ``handle.result()`` — so handles
        submitted together co-batch. With a background pump the submit is
        thread-safe (it runs on the pump thread between engine steps, so a
        burst of submits from many threads still lands in one admission
        round) and decoding starts immediately. Raises ``OverloadError``
        when the overload policy refuses admission."""
        def _submit():
            req = self.engine.enqueue(prompt, params, session=session,
                                      token_ids=token_ids)
            h = Handle(self, req)
            self._handles[req.rid] = h
            return h
        return self._call(_submit)

    def cancel(self, handle: Handle) -> bool:
        """Cancel a queued or running handle: its slot, private KV pages,
        and radix pins are released immediately; the handle keeps whatever
        partial text was already decoded. Thread-safe under a pump."""
        def _cancel():
            ok = self.engine.cancel(handle.request)
            self._deliver()
            return ok
        return self._call(_cancel)

    # ---- the step loop -----------------------------------------------------
    def step(self) -> StepOutcome:
        """One engine iteration for ALL live requests, then deliver newly
        decoded text to their handles. Returns a ``StepOutcome`` (truthy
        while there is work — see the enum; existing ``while step():``
        loops keep working). With a background pump running, the pump owns
        the loop: calling this from another thread raises."""
        if self.pumping:
            raise RuntimeError(
                "the background pump owns the step loop; wait on handles "
                "(stream()/result()) or run_until_idle() instead")
        return self._step_impl()

    def _step_impl(self) -> StepOutcome:
        progressed = self.engine.step()
        self._deliver()
        if progressed:
            return StepOutcome.PROGRESSED
        # queue non-empty with no progress => every queued request is in
        # admission backoff; engine.step() already slept toward the
        # earliest retry, so WAITING loops are back-pressured, not busy
        return (StepOutcome.WAITING if self.engine._queue
                else StepOutcome.IDLE)

    def run_until_idle(self):
        """Drain everything currently queued or running (blocks on the
        pump when one is running)."""
        if self._pump is not None:
            self._pump.wait_idle()
            return
        while self.step():
            pass

    def _deliver(self):
        """Distribute newly decoded (stop-trimmed) text to live handles —
        the streaming counterpart of the engine's one-host-sync-per-chunk
        contract: at most one delivery per handle per chunk.

        Increments are decoded from the NEW tokens only (O(chunk), not
        O(output so far)), holding back a trailing incomplete UTF-8
        sequence so a multi-byte character split across chunk syncs is
        delivered whole once its last byte lands — the concatenated stream
        always equals ``result()``."""
        eng = self.engine
        by_rid = {s.request.rid: s for s in eng.slots if s.request is not None}
        for rid, h in list(self._handles.items()):
            req = h.request
            if req.finished:
                ids = req.output_ids or []
                tail = eng.tokenizer.decode(ids[h._sent:])
                h._sent = len(ids)
                if tail:
                    h._push(tail)
                    eng._stream_chunks += 1
                del self._handles[rid]
                continue
            slot = by_rid.get(rid)
            if slot is None:
                continue
            avail = len(slot.generated) - _utf8_holdback(slot.generated)
            if avail > h._sent:
                piece = eng.tokenizer.decode(slot.generated[h._sent:avail])
                h._sent = avail
                if piece:                       # all-specials chunks skip
                    h._push(piece)
                    eng._stream_chunks += 1
