"""Fault-tolerance layer for the serving stack: typed request statuses,
retry policy, fault taxonomy, and a deterministic fault injector.

The FaaS layer the seed models (core/faas.py, core/workflow.py) gives every
stage a timeout, a retry-with-backoff policy, and durable state it can be
replayed from — this module mirrors those semantics onto the real engine so
a device error, a stuck jit step, or a poisoned request fails ONE handle
instead of crashing the pump and stranding every co-batched session.

Taxonomy (all subclasses of ``RuntimeError``):

* ``TransientFault`` — engine-level and plausibly temporary (injected device
  error, pool contention). The jit-dispatch layer (serving/programs.py)
  retries these per ``RetryPolicy`` with exponential backoff + jitter.
* ``RequestFault`` — permanently scoped to one request (bad params that
  escaped validation, a request that can never fit the page pool). Fails
  only the owning handle; co-batched requests are untouched.
* ``CorruptionError`` — a ``RequestFault`` raised when a page / snapshot id
  is detected corrupt at the point it would be consumed.
* ``DeadlineExceeded`` — recorded on handles cancelled by deadline expiry.
* ``DeadLetterError`` — retries exhausted; recorded on the dead-lettered
  handle(s) (``handle.exception()``).
* ``OverloadError`` — admission refused by the overload policy (bounded
  queue full, per-class depth cap, or circuit breaker open). Raised
  synchronously from ``enqueue``/``submit`` — the request never existed.
* ``ShedError`` — an ``OverloadError`` recorded on an *accepted* request
  that the scheduler later shed from the queue (aged out, or its remaining
  deadline can no longer cover the predicted service time). The handle
  terminates with status ``"shed"`` instead of limping to a timeout.
* ``PumpStalledError`` — the background pump (serving/pump.py) stopped
  heartbeating while work was pending; surfaced to waiters instead of a
  silent hang.

Retry safety: injected faults are raised *before* the device dispatch, so a
retried call re-runs bit-identically. A real exception escaping a jit call
is never retried — with buffer donation on, the inputs may already be
consumed — it fails the affected handles instead (the scheduler's
failure-isolation paths) and the pump keeps serving.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import random
import threading
import time
from typing import Dict, Optional

__all__ = ["RequestStatus", "RetryPolicy", "FaultInjector", "FaultError",
           "TransientFault", "RequestFault", "CorruptionError",
           "DeadlineExceeded", "DeadLetterError", "OverloadError",
           "ShedError", "PumpStalledError"]


class RequestStatus(str, enum.Enum):
    """Lifecycle of a request/handle. Every request terminates in exactly
    one of the five terminal states — step-loop exceptions no longer
    propagate to whichever caller happened to be pumping."""
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"       # finalized normally (EOS / budget / stop)
    CANCELLED = "cancelled"       # explicit cancel(); partial output kept
    TIMED_OUT = "timed_out"       # deadline_s expired; partial output kept
    FAILED = "failed"             # dead-lettered; handle.exception() has why
    SHED = "shed"                 # dropped by overload policy before running

    @property
    def terminal(self) -> bool:
        return self in (RequestStatus.COMPLETED, RequestStatus.CANCELLED,
                        RequestStatus.TIMED_OUT, RequestStatus.FAILED,
                        RequestStatus.SHED)


class FaultError(RuntimeError):
    """Base of the serving fault taxonomy."""


class TransientFault(FaultError):
    """Engine-level, plausibly temporary: retried per ``RetryPolicy``."""


class RequestFault(FaultError):
    """Permanently scoped to one request: fails only that handle."""


class CorruptionError(RequestFault):
    """A corrupted page / snapshot id detected before it was consumed."""


class DeadlineExceeded(FaultError):
    """The request's ``deadline_s`` elapsed before it finished."""


class DeadLetterError(FaultError):
    """Bounded retries exhausted; the request is dead-lettered."""


class OverloadError(FaultError):
    """Admission refused by the overload policy (queue/class caps, circuit
    breaker). Raised synchronously from ``enqueue``/``submit``."""


class ShedError(OverloadError):
    """An accepted request shed from the queue by the overload policy
    (queue-age cap, or predicted service time exceeds the remaining
    deadline). Recorded on handles with terminal status ``"shed"``."""


class PumpStalledError(FaultError):
    """The background pump stopped heartbeating (or died) while work was
    pending; raised to blocked waiters instead of hanging them."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with jitter — the ``core/workflow.Retry`` shape
    applied to jit dispatches and paged-admission retries.

    max_attempts: total tries (first attempt included) before dead-letter.
    backoff_s:    delay before the first retry.
    backoff_rate: multiplier per further retry.
    jitter:       fractional random spread added on top (0 = deterministic),
                  decorrelating co-queued retries so they don't re-collide.
    """
    max_attempts: int = 3
    backoff_s: float = 0.02
    backoff_rate: float = 2.0
    jitter: float = 0.25

    def delay(self, attempt: int, rng: Optional[random.Random] = None) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        d = self.backoff_s * self.backoff_rate ** max(attempt - 1, 0)
        if self.jitter:
            d *= 1.0 + self.jitter * (rng or random).random()
        return d


class FaultInjector:
    """Deterministic chaos hooks for the scheduler / kvpool / jit-program
    layers (tests/test_chaos.py, ``benchmarks/session_bench.py --chaos``).

    Hook sites (strings): the jit dispatches ``"prefill"``, ``"extend"``,
    ``"extend_paged"``, ``"decode"``, ``"verify"``, ``"snap_capture"``,
    ``"snap_restore"`` (checked by ``EnginePrograms`` via :meth:`check`,
    which may raise or stall) and the allocators ``"pool.alloc"`` /
    ``"snap.alloc"`` (checked via :meth:`take`, which denies the allocation
    — simulated exhaustion — instead of raising).

    Two arming modes compose:

    * **counted** — ``fail_next(site, n)`` / ``exhaust_next(site, n)`` /
      ``stall_next(site, n, stall_s)`` arm the next ``n`` hits of a site.
    * **rate** — ``rates={"decode": 0.05}`` fires a ``TransientFault`` on
      ~5% of hits, drawn from a seeded ``random.Random`` so a chaos run is
      reproducible given the seed and the same call sequence.

    ``injected`` counts every fired fault by site (suffix ``.deny`` for
    allocator denials, ``.stall`` for stalls).

    Thread safety: hook sites are hit from the pump thread while tests and
    callers arm faults from their own threads, so every armed-queue pop,
    seeded-RNG draw, and counter bump happens under one lock. (The lock is
    *not* held across a stall sleep — a stall must not serialize unrelated
    sites.) Determinism under concurrency is per-thread-interleaving: a
    single-threaded call sequence replays bit-identically given the seed.
    """

    def __init__(self, seed: int = 0, rates: Optional[Dict[str, float]] = None):
        self._rng = random.Random(seed)
        self.rates: Dict[str, float] = dict(rates or {})
        self._armed: Dict[str, list] = collections.defaultdict(list)
        self._deny: collections.Counter = collections.Counter()
        self.injected: collections.Counter = collections.Counter()
        self._lock = threading.Lock()

    # ---- arming ------------------------------------------------------------
    def fail_next(self, site: str, n: int = 1, *, exc=TransientFault,
                  msg: Optional[str] = None):
        """Arm the next ``n`` dispatches of ``site`` to raise ``exc``."""
        with self._lock:
            for _ in range(n):
                self._armed[site].append(
                    ("raise", exc(msg or f"injected fault at {site!r}")))

    def exhaust_next(self, site: str = "pool.alloc", n: int = 1):
        """Arm the next ``n`` allocations at ``site`` to be denied (the
        allocator behaves as if exhausted)."""
        with self._lock:
            self._deny[site] += n

    def stall_next(self, site: str, n: int = 1, *, stall_s: float = 0.05):
        """Arm the next ``n`` dispatches of ``site`` to stall ``stall_s``
        (a stuck step for the watchdog to notice)."""
        with self._lock:
            for _ in range(n):
                self._armed[site].append(("stall", stall_s))

    # ---- hook points -------------------------------------------------------
    def check(self, site: str):
        """Dispatch hook: consume one armed action (raise / stall) or roll
        the site's rate for a ``TransientFault``."""
        with self._lock:
            q = self._armed.get(site)
            if q:
                kind, val = q.pop(0)
                if kind == "stall":
                    self.injected[site + ".stall"] += 1
                else:
                    self.injected[site] += 1
                    raise val
            else:
                r = self.rates.get(site)
                if not (r and self._rng.random() < r):
                    return
                self.injected[site] += 1
                raise TransientFault(
                    f"injected fault at {site!r} (rate {r})")
        time.sleep(val)  # stall: sleep outside the lock

    def take(self, site: str) -> bool:
        """Allocator hook: True = deny this allocation (simulated
        exhaustion). Never raises — the caller's normal out-of-resource
        path (eviction, admission backoff, skipped capture) must handle it."""
        with self._lock:
            if self._deny.get(site, 0) > 0:
                self._deny[site] -= 1
                self.injected[site + ".deny"] += 1
                return True
            r = self.rates.get(site)
            if r and self._rng.random() < r:
                self.injected[site + ".deny"] += 1
                return True
            return False
