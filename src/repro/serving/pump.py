"""Background pump: the always-on serving loop (``LLMServer(pump=True)``).

Without a pump the server is cooperative — nothing advances until some
caller drives ``step()``. The pump makes the server a standing service: a
daemon thread owns the engine loop, and caller threads interact through two
thread-safe surfaces:

* **the command queue** — ``submit`` / ``cancel`` / ``open_session`` /
  ``close_session`` / ``stats`` route their engine work through
  :meth:`call`, which runs the thunk *on the pump thread* between engine
  steps. JAX dispatch is not thread-safe across our program cache
  (fame/fusion.py learned this first), so the pump thread is the only
  thread that ever touches the engine. Every command pending at the top of
  a loop iteration executes before the next ``step()`` — a burst of submits
  from N workflow threads lands in one admission round and co-batches.
* **the progress condition** — handle streams (``Handle.stream()`` /
  ``result()``) and ``wait_idle()`` block on it; the pump notifies after
  every engine step, right after delivering freshly decoded text.

Liveness watchdog: the pump heartbeats every loop iteration. A waiter whose
wait outlives ``stall_timeout_s`` without a heartbeat — the pump is wedged
inside a jit dispatch, or its thread died — raises a typed
``PumpStalledError`` instead of hanging silently. A pump-loop crash
(engine-level exception that escaped the scheduler's failure isolation)
fails every outstanding request with the cause and wakes all waiters, so no
handle is ever stranded.

Shutdown: ``close()`` (or leaving the ``with LLMServer(...)`` block) stops
the loop; outstanding requests are cancelled *on the pump thread* before it
exits, so late waiters see a terminal ``CANCELLED`` status, not a hang.
``close(drain=True)`` finishes all queued/running work first.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from repro.serving.faults import PumpStalledError

__all__ = ["PumpConfig", "BackgroundPump"]


@dataclasses.dataclass(frozen=True)
class PumpConfig:
    """Pump knobs.

    stall_timeout_s: heartbeat staleness after which waiters raise
                     ``PumpStalledError``. Must exceed the longest honest
                     engine step (a cold jit compile easily takes seconds —
                     keep this generous).
    poll_s:          waiter re-check period; also the idle loop's nap, so it
                     bounds how fast an idle pump notices new commands.
    """
    stall_timeout_s: float = 30.0
    poll_s: float = 0.05


class BackgroundPump:
    """Daemon thread driving ``server._step_impl()``; see module docstring."""

    def __init__(self, server, cfg: Optional[PumpConfig] = None):
        self.server = server
        self.cfg = cfg or PumpConfig()
        self._cv = threading.Condition()
        self._commands: "collections.deque" = collections.deque()
        self._stop = False
        self._crashed: Optional[BaseException] = None
        self._last_beat = time.monotonic()
        self._idle = threading.Event()
        self.steps = 0                  # pump loop iterations that stepped
        self.stall_notices = 0          # waiter-observed stalls (typed raises)
        self.thread = threading.Thread(target=self._loop,
                                       name="llmserver-pump", daemon=True)

    def start(self):
        self.thread.start()

    @property
    def alive(self) -> bool:
        return self.thread.is_alive() and self._crashed is None

    # ---- caller side -------------------------------------------------------
    def call(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` on the pump thread (between engine steps) and return
        its result; exceptions propagate to the caller. Re-entrant: called
        from the pump thread itself it just runs ``fn``."""
        if threading.current_thread() is self.thread:
            return fn()
        box: dict = {}
        done = threading.Event()
        with self._cv:
            if self._stop or not self.alive:
                raise PumpStalledError(
                    "pump is closed" if self._stop else
                    f"pump is dead: {self._crashed!r}")
            self._commands.append((fn, box, done))
            self._cv.notify_all()
        while not done.wait(self.cfg.poll_s):
            self._check_live("a queued command")
        if "exc" in box:
            raise box["exc"]
        return box["result"]

    def wait_progress(self):
        """Block until the pump completes another loop iteration (bounded
        by ``poll_s``); raises ``PumpStalledError`` on a stalled/dead pump.
        Handle streams call this between emptiness checks. A *cleanly*
        closed pump returns instead of raising: close() already cancelled
        every outstanding request on the pump thread, so the waiter's next
        ``request.finished`` check terminates its loop — a handle blocked
        in ``result()`` while another thread calls ``close()`` gets its
        partial CANCELLED output, not a spurious stall error."""
        with self._cv:
            self._cv.wait(self.cfg.poll_s)
        if self._closed_cleanly:
            return
        self._check_live("engine progress")

    def wait_idle(self):
        """Block until the engine is fully drained (no queued requests, no
        active slots, no pending commands). Returns (drained-by-
        cancellation) if the pump closes cleanly mid-wait."""
        while not self._idle.wait(self.cfg.poll_s):
            if self._closed_cleanly:
                return
            self._check_live("the engine to drain")

    def close(self, drain: bool = False, join_timeout_s: Optional[float] = None):
        """Stop the pump. ``drain=True`` finishes all outstanding work
        first; otherwise outstanding requests are cancelled on the pump
        thread before it exits (terminal CANCELLED, never stranded).

        Idempotent and race-safe: a second ``close()`` — sequential or
        racing the first from another thread — just joins the already-
        stopping thread; it never raises and never deadlocks (waiters see
        ``_closed_cleanly`` and unblock, see wait_progress)."""
        if drain and self._crashed is None and not self._stop \
                and self.thread.is_alive():
            try:
                self.wait_idle()
            except PumpStalledError:
                pass                    # crashed/stalled mid-drain: stop anyway
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if not self.thread.is_alive():
            return
        self.thread.join(join_timeout_s if join_timeout_s is not None
                         else self.cfg.stall_timeout_s)

    @property
    def _closed_cleanly(self) -> bool:
        """True once a requested close() has fully stopped the loop (no
        crash): the thread exited after cancelling all outstanding work."""
        return self._stop and self._crashed is None \
            and not self.thread.is_alive()

    def _check_live(self, waiting_for: str):
        if self._crashed is not None:
            raise PumpStalledError(
                f"pump crashed while waiting for {waiting_for}: "
                f"{self._crashed!r}") from self._crashed
        if not self.thread.is_alive():
            raise PumpStalledError(
                f"pump thread died while waiting for {waiting_for}")
        stale = time.monotonic() - self._last_beat
        if stale > self.cfg.stall_timeout_s:
            self.stall_notices += 1
            raise PumpStalledError(
                f"pump heartbeat stale for {stale:.1f}s "
                f"(stall_timeout_s={self.cfg.stall_timeout_s}) while "
                f"waiting for {waiting_for} — a dispatch is likely wedged")

    # ---- pump thread -------------------------------------------------------
    def _loop(self):
        try:
            while True:
                with self._cv:
                    cmds = list(self._commands)
                    self._commands.clear()
                    stop = self._stop
                if cmds:
                    self._idle.clear()
                for fn, box, done in cmds:
                    try:
                        box["result"] = fn()
                    except BaseException as e:
                        box["exc"] = e
                    done.set()
                if stop:
                    self._cancel_outstanding()
                    with self._cv:
                        self._cv.notify_all()
                    return
                outcome = self.server._step_impl()
                self.steps += 1
                with self._cv:
                    self._last_beat = time.monotonic()
                    self._cv.notify_all()
                if outcome:             # PROGRESSED or WAITING (engine step
                    self._idle.clear()  # already back-pressured internally)
                    continue
                self._idle.set()
                with self._cv:
                    if not self._commands and not self._stop:
                        self._cv.wait(self.cfg.poll_s)
        except BaseException as e:      # engine-level crash: fail everything
            self._crashed = e
            self._fail_outstanding(e)
            with self._cv:
                self._cv.notify_all()

    def _cancel_outstanding(self):
        eng = self.server.engine
        for h in list(self.server._handles.values()):
            if not h.request.finished:
                eng.cancel(h.request)
        self.server._deliver()

    def _fail_outstanding(self, exc: BaseException):
        """Best-effort: the engine may be in an arbitrary state — terminate
        every live handle typed so waiters unblock with a cause."""
        try:
            eng = self.server.engine
            for h in list(self.server._handles.values()):
                if not h.request.finished:
                    try:
                        eng._abort(h.request, "failed", PumpStalledError(
                            f"pump crashed mid-serve: {exc!r}"))
                    except BaseException:
                        h.request.status = "failed"
                        h.request.error = exc
                        h.request.finished = True
            self.server._deliver()
        except BaseException:
            pass
