"""Logical-axis → mesh-axis sharding rules (MaxText-style), per phase.

Parameters carry *logical* axis names (see models/layers.py ParamDef); a rule
set maps those to physical mesh axes. Single-pod mesh: ("data", "model");
multi-pod adds a leading "pod" axis that joins the FSDP/batch dimension.

Baseline layout (paper-faithful starting point; §Perf iterates from here):
  - weights:    TP over "model" (heads / mlp / vocab / rnn / inner),
                FSDP over ("pod","data") on the embed dim
  - batch:      over ("pod","data")
  - KV cache:   sequence-sharded over "model" (decode context parallelism —
                the softmax/psum combine is handled by SPMD partitioning)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def rules_for(mesh: Mesh, phase: str, *, shard_batch: bool = True,
              weight_stationary: bool = False,
              expert_parallel: bool = False) -> dict:
    """Baseline layout, or the §Perf `weight_stationary` decode layout.

    weight_stationary (decode only): activations are tiny at one-token-per-
    sequence, so REPLICATE them over the batch axes and fully 2D-shard every
    weight — matmuls contract against sharded weights and psum small
    activations instead of all-gathering multi-GB weights each layer (the
    baseline's dominant decode collective). KV caches stay (batch→data,
    seq→model)-sharded.
    """
    axes = mesh.axis_names
    multi_pod = "pod" in axes
    fsdp = ("pod", "data") if multi_pod else ("data",)
    batch = fsdp if shard_batch else ()
    rules = {
        "phase": phase,
        "batch": batch,
        "cache_batch": batch,
        "fsdp": fsdp,
        "vocab": ("model",),
        "embed": fsdp,
        "heads": ("model",),
        "kv_heads": (),
        "head_dim": (),
        "mlp": ("model",),
        "experts": (),
        "moe_embed": fsdp,
        "moe_tokens": batch,      # xe group dim (default: follow the batch)
        "experts_run": (),        # xe expert dim (EP mode: the fsdp axis)
        "rnn": ("model",),
        # xLSTM inner dims: replicated over `model` (§Perf iteration 2) —
        # TP of a 2048-wide recurrence over 16 shards made every mLSTM chunk
        # all-gather its state/qkv (45GB/step); a 350M-class recurrent model
        # wants pure data parallelism on this mesh.
        "inner": (),
        "inner_out": (),
        "slstm_inner": (),
        "conv": (),
        "norm": (),
        "layers": (),
        "kv_seq": ("model",),
        None: (),
    }
    if weight_stationary:
        assert phase == "decode", "weight-stationary layout is a decode mode"
        # Activations replicate; weights keep their 2D sharding and are
        # contracted IN PLACE (psum of small partials). Caches keep the
        # sharded batch via "cache_batch".
        rules["batch"] = ()
        rules["moe_tokens"] = ()
    if expert_parallel:
        # experts live on the fsdp axis; tokens all-to-all to their expert
        rules["experts"] = fsdp
        rules["moe_embed"] = ()
        rules["experts_run"] = fsdp
        rules["moe_tokens"] = ()
    return rules


def _axes_to_spec(axes: Sequence[Optional[str]], rules: dict) -> P:
    out = []
    for a in axes:
        phys = rules.get(a, ())
        if isinstance(phys, str):
            phys = (phys,)
        if len(phys) == 0:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(tuple(phys))
    return P(*out)


def param_pspecs(logical_tree, rules: dict):
    """Tree of logical-axis tuples -> tree of PartitionSpec."""
    return jax.tree.map(
        lambda axes: _axes_to_spec(axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------


def cache_pspecs(cfg, rules: dict):
    """PartitionSpecs mirroring ``transformer.cache_spec`` structurally.

    Attention KV caches [B, W, K, hd] are sequence-sharded over "model";
    recurrent/mLSTM/sLSTM states shard their channel dim over "model".
    """
    from repro.configs import base as cfgbase

    batch = rules.get("cache_batch", rules["batch"])
    b = batch if len(batch) > 1 else (batch[0] if batch else None)
    kv = rules["kv_seq"][0] if rules["kv_seq"] else None
    ch = "model"

    def block_specs(kind, lead):
        if kind in (cfgbase.ATTN, cfgbase.ATTN_MOE, cfgbase.LOCAL_ATTN):
            s = P(*lead, b, kv, None, None)
            return {"k": s, "v": s}
        if kind == cfgbase.RECURRENT:
            return {"h": P(*lead, b, ch), "conv": P(*lead, b, None, ch)}
        if kind == cfgbase.MLSTM:
            return {"state": (P(*lead, b, None, None, None),  # C [B,H,mhd,mhd]
                              P(*lead, b, None, None),         # n [B,H,mhd]
                              P(*lead, b, None)),               # m [B,H]
                    "conv": P(*lead, b, None, None)}
        if kind == cfgbase.SLSTM:
            s = P(*lead, b, None)        # replicated channels (see rules)
            return {"state": (s, s, s, s)}
        raise ValueError(kind)

    out = {"scan": {}}
    for i, kind in enumerate(cfg.pattern):
        out["scan"][f"sub{i}"] = block_specs(kind, (None,))
    for j, kind in enumerate(cfg.tail_kinds):
        out[f"tail{j}"] = block_specs(kind, ())
    return out


# ---------------------------------------------------------------------------
# Batch / IO shardings
# ---------------------------------------------------------------------------


def batch_pspecs(cfg, rules: dict, phase: str):
    batch = rules["batch"]
    b = batch if len(batch) != 1 else (batch[0] if batch else None)
    if not batch:
        b = None
    specs = {"positions": P(b, None)}
    if cfg.modality == "audio_frames":
        specs["frames"] = P(b, None, None)
    else:
        specs["tokens"] = P(b, None)
    if phase == "train":
        specs["labels"] = P(b, None)
    return specs


# ---------------------------------------------------------------------------
# Activation-constraint context (used inside model code via current_rules())
# ---------------------------------------------------------------------------

_tls = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: dict):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = prev


def constrain(x, *axes):
    """with_sharding_constraint by logical axes, no-op outside a rules ctx."""
    rules = current_rules()
    if rules is None:
        return x
    spec = _axes_to_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, spec)
