"""Logical-axis → mesh-axis sharding rules (MaxText-style), per phase.

Parameters carry *logical* axis names (see models/layers.py ParamDef); a rule
set maps those to physical mesh axes. Single-pod mesh: ("data", "model");
multi-pod adds a leading "pod" axis that joins the FSDP/batch dimension.

Baseline layout (paper-faithful starting point; §Perf iterates from here):
  - weights:    TP over "model" (heads / mlp / vocab / rnn / inner),
                FSDP over ("pod","data") on the embed dim
  - batch:      over ("pod","data")
  - KV cache:   sequence-sharded over "model" (decode context parallelism —
                the softmax/psum combine is handled by SPMD partitioning)

Serving layout (phase="serve"): the serving stack's acceptance bar is
BIT-IDENTICAL greedy outputs vs single device, which rules out any layout
that splits a contraction dimension across devices (partial matmuls +
psum/AllReduce re-associate float sums). The serve rules therefore shard
only *batch-like* dims (slot/page/snapshot-row batch → "data"; q heads, KV
heads, expert index → "model" — attention heads and experts are batch dims
of their einsums) and *output* dims of matmuls whose contraction side stays
replicated (vocab, mlp-up, rnn-up). Down-projections keep their contraction
axis replicated via the ``*_in`` weight axes, and the ``*_act`` activation
keys force an all-gather right before each down-projection so the
contraction itself runs identically on every device. All-gathers move bits
but never re-associate sums, so the whole forward pass stays bit-exact.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def rules_for(mesh: Mesh, phase: str, *, shard_batch: bool = True,
              weight_stationary: bool = False,
              expert_parallel: bool = False) -> dict:
    """Baseline layout, or the §Perf `weight_stationary` decode layout.

    weight_stationary (decode only): activations are tiny at one-token-per-
    sequence, so REPLICATE them over the batch axes and fully 2D-shard every
    weight — matmuls contract against sharded weights and psum small
    activations instead of all-gathering multi-GB weights each layer (the
    baseline's dominant decode collective). KV caches stay (batch→data,
    seq→model)-sharded.
    """
    axes = mesh.axis_names
    multi_pod = "pod" in axes
    fsdp = ("pod", "data") if multi_pod else ("data",)
    batch = fsdp if shard_batch else ()
    if phase == "serve":
        return _serve_rules(mesh, batch)
    rules = {
        "phase": phase,
        "mesh": mesh,
        "batch": batch,
        "cache_batch": batch,
        "fsdp": fsdp,
        "vocab": ("model",),
        "embed": fsdp,
        "heads": ("model",),
        "heads_in": ("model",),   # wo contraction side (serve: replicated)
        "heads_act": ("model",),  # attention output pre-wo (serve: gathered)
        "kv_heads": (),
        "head_dim": (),
        "mlp": ("model",),
        "mlp_in": ("model",),     # dense-MLP wo contraction side
        "mlp_act": ("model",),    # MLP hidden pre-wo
        "experts": (),
        "moe_mlp": ("model",),    # MoE wi/wo hidden dim
        "moe_embed": fsdp,
        "moe_tokens": batch,      # xe group dim (default: follow the batch)
        "experts_run": (),        # xe expert dim (EP mode: the fsdp axis)
        "rnn": ("model",),
        "rnn_in": ("model",),     # RG-LRU wo contraction side
        "rnn_act": ("model",),    # RG-LRU mixed output pre-wo
        # xLSTM inner dims: replicated over `model` (§Perf iteration 2) —
        # TP of a 2048-wide recurrence over 16 shards made every mLSTM chunk
        # all-gather its state/qkv (45GB/step); a 350M-class recurrent model
        # wants pure data parallelism on this mesh.
        "inner": (),
        "inner_out": (),
        "slstm_inner": (),
        "conv": (),
        "norm": (),
        "layers": (),
        "kv_seq": ("model",),
        None: (),
    }
    if weight_stationary:
        assert phase == "decode", "weight-stationary layout is a decode mode"
        # Activations replicate; weights keep their 2D sharding and are
        # contracted IN PLACE (psum of small partials). Caches keep the
        # sharded batch via "cache_batch".
        rules["batch"] = ()
        rules["moe_tokens"] = ()
    if expert_parallel:
        # experts live on the fsdp axis; tokens all-to-all to their expert
        rules["experts"] = fsdp
        rules["moe_embed"] = ()
        rules["experts_run"] = fsdp
        rules["moe_tokens"] = ()
    return rules


def _serve_rules(mesh: Mesh, batch) -> dict:
    """The bit-exact serving layout (see module docstring).

    batch/page/row axes → "data"; per-head and per-expert batch dims plus
    replicated-contraction output dims (vocab / mlp-up / rnn-up) → "model";
    every contraction side (embed, ``*_in``) and every pre-down-projection
    activation (``*_act``) replicated, so no float sum is ever split.
    """
    return {
        "phase": "serve",
        "mesh": mesh,
        "batch": batch,
        "cache_batch": batch,
        "fsdp": (),
        "vocab": ("model",),       # unembed output dim; embed-table rows
        "embed": (),               # every input contraction: replicated
        "heads": ("model",),       # q heads: a batch dim of attention
        "heads_in": (),            # wo contracts over heads → replicated
        "heads_act": (),           # gather attention output before wo
        "kv_heads": ("model",),    # KV cache / page-pool head dim
        "head_dim": (),
        "mlp": ("model",),         # wi/wg output dim (contraction replicated)
        "mlp_in": (),              # wo contracts over F → replicated
        "mlp_act": (),             # gather hidden before wo
        "experts": ("model",),     # expert parallelism: E is a batch dim
        "moe_mlp": (),             # per-expert F: contracted by MoE wo
        "moe_embed": (),
        "moe_tokens": (),
        "experts_run": ("model",),  # dispatched tokens follow their expert
        "rnn": ("model",),         # RG-LRU channels: elementwise recurrence
        "rnn_in": (),              # wo contracts over R → replicated
        "rnn_act": (),             # gather mixed output before wo
        # xLSTM / sLSTM inner dims stay replicated (see baseline comment —
        # and their qkv projections contract over "inner", which a sharded
        # inner dim would split)
        "inner": (),
        "inner_out": (),
        "slstm_inner": (),
        "conv": (),
        "norm": (),
        "layers": (),
        "kv_seq": (),              # no sequence parallelism: the softmax
                                   # combine re-associates sums (not bit-safe)
        None: (),
    }


def _axes_to_spec(axes: Sequence[Optional[str]], rules: dict) -> P:
    out = []
    for a in axes:
        phys = rules.get(a, ())
        if isinstance(phys, str):
            phys = (phys,)
        if len(phys) == 0:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(tuple(phys))
    return P(*out)


def param_pspecs(logical_tree, rules: dict):
    """Tree of logical-axis tuples -> tree of PartitionSpec."""
    return jax.tree.map(
        lambda axes: _axes_to_spec(axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------


def cache_pspecs(cfg, rules: dict):
    """PartitionSpecs mirroring ``transformer.cache_spec`` structurally.

    Every physical axis comes from the rule set: train/decode phases
    sequence-shard attention KV over "model" and channel-shard recurrent
    state; the serve phase instead shards the batch axis (slot / page /
    snapshot row) over "data" and the KV-head / recurrent-channel dims over
    "model" (both are batch-like — bit-safe). The same specs cover the dense
    per-slot cache, the paged page pool (batch = pages) and the snapshot
    arena (batch = rows), which all reuse the cache pytree structure.
    """
    from repro.configs import base as cfgbase

    batch = rules.get("cache_batch", rules["batch"])
    b = batch if len(batch) > 1 else (batch[0] if batch else None)
    kv = rules["kv_seq"][0] if rules["kv_seq"] else None
    kvh = rules.get("kv_heads", ())
    kvh = kvh[0] if kvh else None
    rnn = rules.get("rnn", ())
    ch = rnn[0] if rnn else None

    def block_specs(kind, lead):
        if kind in (cfgbase.ATTN, cfgbase.ATTN_MOE, cfgbase.LOCAL_ATTN):
            s = P(*lead, b, kv, kvh, None)
            return {"k": s, "v": s}
        if kind == cfgbase.RECURRENT:
            return {"h": P(*lead, b, ch), "conv": P(*lead, b, None, ch)}
        if kind == cfgbase.MLSTM:
            return {"state": (P(*lead, b, None, None, None),  # C [B,H,mhd,mhd]
                              P(*lead, b, None, None),         # n [B,H,mhd]
                              P(*lead, b, None)),               # m [B,H]
                    "conv": P(*lead, b, None, None)}
        if kind == cfgbase.SLSTM:
            s = P(*lead, b, None)        # replicated channels (see rules)
            return {"state": (s, s, s, s)}
        raise ValueError(kind)

    out = {"scan": {}}
    for i, kind in enumerate(cfg.pattern):
        out["scan"][f"sub{i}"] = block_specs(kind, (None,))
    for j, kind in enumerate(cfg.tail_kinds):
        out[f"tail{j}"] = block_specs(kind, ())
    return out


# ---------------------------------------------------------------------------
# Batch / IO shardings
# ---------------------------------------------------------------------------


def batch_pspecs(cfg, rules: dict, phase: str):
    batch = rules["batch"]
    b = batch if len(batch) != 1 else (batch[0] if batch else None)
    if not batch:
        b = None
    specs = {"positions": P(b, None)}
    if cfg.modality == "audio_frames":
        specs["frames"] = P(b, None, None)
    else:
        specs["tokens"] = P(b, None)
    if phase == "train":
        specs["labels"] = P(b, None)
    return specs


# ---------------------------------------------------------------------------
# Activation-constraint context (used inside model code via current_rules())
# ---------------------------------------------------------------------------

_tls = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: dict):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = prev


def constrain(x, *axes):
    """with_sharding_constraint by logical axes, no-op outside a rules ctx.

    Rule sets carry their mesh, so the constraint is a full ``NamedSharding``
    — usable from any call site (the serving jits run under ``use_rules``
    with no ambient ``with mesh:`` context manager). Constraints whose spec
    does not divide the dim are ignored by the partitioner (replicated),
    which keeps small test configs (e.g. 2 KV heads on a 4-way "model"
    axis) correct — just unsharded on that dim.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = _axes_to_spec(axes, rules)
    mesh = rules.get("mesh")
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Committed placement (serving): device_put with divisibility fallback
# ---------------------------------------------------------------------------


def _divisible_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis extent does not divide the dim.

    ``jax.device_put`` (unlike in-jit constraints) refuses uneven shardings;
    replicating the offending dim preserves values exactly, so a config
    whose KV heads / slots / pages don't divide the mesh still serves
    correctly — that dim just stays unsharded.
    """
    out = []
    for i, dim in enumerate(shape):
        entry = spec[i] if i < len(spec) else None
        if entry is not None:
            names = (entry,) if isinstance(entry, str) else tuple(entry)
            extent = 1
            for n in names:
                extent *= mesh.shape[n]
            if extent == 0 or dim % extent != 0:
                entry = None
        out.append(entry)
    return P(*out)


def shard_put(tree, spec_tree, mesh: Mesh):
    """``device_put`` a pytree with per-leaf ``PartitionSpec``s (same
    structure), falling back to replication on non-divisible dims."""
    def _put(x, spec):
        return jax.device_put(
            x, NamedSharding(mesh, _divisible_spec(x.shape, spec, mesh)))
    return jax.tree.map(_put, tree, spec_tree)
