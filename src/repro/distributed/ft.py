"""Fault tolerance for the training/serving loops.

- ``FaultTolerantLoop``: checkpoint/restart supervision — run a step function,
  checkpoint every N steps (async), and on failure restore the latest
  checkpoint and resume (optionally on a *different* device count — elastic).
- ``Heartbeat``: liveness monitor hook (wall-clock watchdog).
- Straggler mitigation for the FaaS layer lives in core/faas.py (speculative
  re-execution); for the synchronous training loop the equivalent lever is
  deterministic data skip-ahead on restart, implemented here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step_dir, restore


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    restarts: int
    final_step: int


class Heartbeat:
    def __init__(self, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self._last = time.monotonic()

    def beat(self):
        self._last = time.monotonic()

    @property
    def alive(self) -> bool:
        return time.monotonic() - self._last < self.timeout_s


class FaultTolerantLoop:
    """Supervised step loop with periodic async checkpoints + auto-restart.

    ``step_fn(state, step) -> state``; ``state`` is a pytree (params, opt,
    data-cursor...). Injected failures (tests) raise from step_fn; the loop
    restores and replays. Data determinism: the data cursor lives IN the
    state, so skip-ahead on restore is automatic.
    """

    def __init__(self, ckpt_dir: str, step_fn: Callable, *,
                 ckpt_every: int = 20, max_restarts: int = 3):
        self.ckpt_dir = ckpt_dir
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.heartbeat = Heartbeat()

    def run(self, state, *, start_step: int = 0, num_steps: int = 100,
            shardings=None) -> tuple:
        step = start_step
        restarts = 0
        steps_run = 0
        if latest_step_dir(self.ckpt_dir) is not None:
            state, step = restore(self.ckpt_dir, state, shardings=shardings)
        while step < num_steps:
            try:
                state = self.step_fn(state, step)
                step += 1
                steps_run += 1
                self.heartbeat.beat()
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except Exception:  # noqa: BLE001 — supervised restart
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                if latest_step_dir(self.ckpt_dir) is not None:
                    state, step = restore(self.ckpt_dir, state, shardings=shardings)
                # else: restart from the initial state (step unchanged)
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, LoopReport(steps_run, restarts, step)
