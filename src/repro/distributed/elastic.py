"""Elastic scaling: re-derive the mesh from surviving devices and reshard.

On node loss the job restarts on fewer chips: ``best_mesh_shape`` picks the
largest valid (data, model) factorization of the surviving device count that
keeps the model axis divisibility constraints, and ``reshard_tree`` places a
restored (host) checkpoint onto the new mesh. Together with
``checkpoint.restore(shardings=...)`` this is restart-elasticity: the same
checkpoint serves any mesh size (tested in tests/test_checkpoint_ft.py and
test_elastic.py).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def best_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                    min_model: int = 1) -> Tuple[int, int]:
    """Largest (data, model) grid for n_devices, preferring the target TP
    width and degrading gracefully (16 → 8 → 4 ... ) when devices are lost."""
    tp = 1
    while tp * 2 <= min(model_parallel, n_devices):
        tp *= 2                                   # largest power-of-two TP
    while tp > min_model and n_devices % tp:
        tp //= 2
    tp = max(tp, min_model)
    return (n_devices // tp, tp)


def make_elastic_mesh(devices: Optional[Sequence] = None, *,
                      model_parallel: int = 16) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    data, model = best_mesh_shape(len(devices), model_parallel=model_parallel)
    import numpy as np
    arr = np.array(devices[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def reshard_tree(tree, mesh: Mesh, pspec_tree):
    """Place a (host or differently-sharded) pytree onto ``mesh``."""
    return jax.tree.map(
        lambda x, ps: jax.device_put(x, NamedSharding(mesh, ps)),
        tree, pspec_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
