"""shard_map collectives: sequence-parallel flash-decode with LSE combine.

The baseline decode path (models/attention.decode_attention under pjit) lets
SPMD partition the softmax over the sequence-sharded KV cache. This module is
the EXPLICIT version — each device computes flash-decode partials (m, l, o)
over its local KV shard and combines with a single fused ``psum`` — used by
the §Perf hillclimb to control the collective schedule precisely (one
all-reduce of [B,H,hd+2] instead of separate max/sum/value reductions).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _local_flash_decode(q, k_shard, v_shard, valid):
    """q [B,K,G,hd]; k/v [B,Wl,K,hd]; valid [B,Wl] -> (m,l,o) partials."""
    s = jnp.einsum("bkgh,bwkh->bkgw", q, k_shard,
                   preferred_element_type=jnp.float32) / math.sqrt(q.shape[-1])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # [B,K,G]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgw,bwkh->bkgh", p.astype(v_shard.dtype), v_shard,
                   preferred_element_type=jnp.float32)
    return m, l, o


def make_seqpar_decode_attention(mesh: Mesh, *, batch_axes=("data",),
                                 seq_axis: str = "model"):
    """Returns decode_attn_fn(q, k_cache, v_cache, cache_len, *, q_per_kv,
    window) with cache sequence-sharded over ``seq_axis``."""

    def decode_attn(q, k_cache, v_cache, cache_len, *, q_per_kv: int,
                    window: Optional[int] = None):
        B, W, K, hd = k_cache.shape
        H = q.shape[2]
        n_shards = mesh.shape[seq_axis]
        Wl = W // n_shards
        b = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

        def body(q_l, k_l, v_l, clen):
            # local seq range of this shard
            r = jax.lax.axis_index(seq_axis)
            pos = r * Wl + jnp.arange(Wl)
            clen_b = jnp.asarray(clen)
            if clen_b.ndim == 0:
                clen_b = clen_b[None]
            n_valid = jnp.minimum(clen_b + 1, W)
            valid = pos[None, :] < n_valid[:, None]
            if window is not None:
                age = (clen_b % W)[:, None] - pos[None, :]
                age = jnp.where(age < 0, age + W, age)
                valid &= age < jnp.minimum(window, n_valid + 1)[:, None]
            qg = q_l.reshape(q_l.shape[0], K, q_per_kv, hd)
            m, l, o = _local_flash_decode(qg, k_l, v_l, valid)
            # one fused LSE combine: psum of (exp-shifted l, o) after global max
            m_g = jax.lax.pmax(m, seq_axis)
            corr = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * corr, seq_axis)
            o_g = jax.lax.psum(o * corr[..., None], seq_axis)
            out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
            return out.reshape(q_l.shape[0], 1, H, hd).astype(q_l.dtype)

        clen_spec = P() if jnp.asarray(cache_len).ndim == 0 else P(b)
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(b, None, None, None),          # q [B,1→B,H,hd] flat
                      P(b, seq_axis, None, None),       # k cache
                      P(b, seq_axis, None, None),       # v cache
                      clen_spec),
            out_specs=P(b, None, None, None),
            check_rep=False,
        )(q.reshape(q.shape[0], H, hd)[:, None], k_cache, v_cache, cache_len)

    return decode_attn
