"""MusicGen-large [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (kv=32 → MHA) d_ff=8192 vocab=2048. [arXiv:2306.05284]

Backbone only: the EnCodec modality frontend is a STUB — ``input_specs()``
supplies precomputed frame embeddings ``[B, S, d_model]`` (sum of the four
codebook embeddings after the delay pattern, as produced by the real frontend);
the backbone predicts the next frame's codes over the 2048-entry codebook.
Standard (non-gated) GELU MLP + LayerNorm + sinusoidal positions, per the paper.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=(ATTN,),
    pos_emb="sinusoidal",
    norm_type="layernorm",
    act="gelu",
    gated_mlp=False,
    modality="audio_frames",
)
