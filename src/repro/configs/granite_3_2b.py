"""Granite-3.0-2B [dense] — GQA (kv=8), tied embeddings.

40L d_model=2048 32H (kv=8) d_ff=8192 vocab=49155 (padded to 49280 for TP).
[hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    pattern=(ATTN,),
    rope_theta=10000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
    tie_embeddings=True,
)
