"""Mixtral-8x22B [moe] — 8 experts top-2, GQA (kv=8), sliding-window attention.

56L d_model=6144 48H (kv=8) d_ff=16384 vocab=32768, MoE 8e top-2.
[arXiv:2401.04088]

SWA window 4096 bounds the decode KV cache ⇒ long_500k is runnable.
"""
from repro.configs.base import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=(ATTN_MOE,),
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
)
