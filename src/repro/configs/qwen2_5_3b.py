"""Qwen2.5-3B [dense] — GQA (kv=2), QKV bias, RoPE theta=1e6.

36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936. [hf:Qwen/Qwen2.5-3B]
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    pattern=(ATTN,),
    attn_bias=True,
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
    tie_embeddings=True,
)
