"""Chameleon-34B [vlm] — early-fusion mixed-modal transformer, qk-norm.

48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536. [arXiv:2405.09818]

Early fusion means images are VQ-tokenized into the SAME 65536-entry vocab as
text, so plain token ids are the native input — the VQ-GAN image tokenizer is
the (stubbed) modality frontend. Chameleon's QK-norm is included: it was the
paper's fix for logit drift in mixed-modal training.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    pattern=(ATTN,),
    qk_norm=True,
    rope_theta=10000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
    modality="vlm_tokens",
)
