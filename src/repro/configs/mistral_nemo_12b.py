"""Mistral-Nemo-12B [dense] — GQA (kv=8), head_dim=128 decoupled from d/H, 128k ctx.

40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072.
[hf:mistralai/Mistral-Nemo-Base-2407]
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,              # explicit: NOT d_model // num_heads (=160)
    d_ff=14336,
    vocab_size=131072,
    pattern=(ATTN,),
    rope_theta=1_000_000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq=131072,
)
