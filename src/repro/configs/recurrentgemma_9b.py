"""RecurrentGemma-9B [hybrid] — Griffin: RG-LRU + local attention, 2:1 pattern.

38L d_model=4096 16H (kv=1 → MQA) d_ff=12288 vocab=256000. [arXiv:2402.19427]

Pattern: (recurrent, recurrent, local_attn) repeating; 38 = 12×3 + 2 trailing
recurrent layers. Local attention window 2048 and O(1) RG-LRU state bound the
decode state ⇒ long_500k runs.
"""
from repro.configs.base import LOCAL_ATTN, RECURRENT, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=(RECURRENT, RECURRENT, LOCAL_ATTN),
    local_window=2048,
    rglru_dim=4096,
    conv1d_width=4,
    rope_theta=10000.0,
    norm_type="rmsnorm",
    act="gelu",            # Gemma-style GeGLU
    gated_mlp=True,
    logit_softcap=30.0,
)
