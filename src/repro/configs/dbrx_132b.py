"""DBRX-132B [moe] — 16 experts top-4 (fine-grained), GQA (kv=8).

40L d_model=6144 48H (kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
[hf:databricks/dbrx-base]
"""
from repro.configs.base import ATTN_MOE, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    pattern=(ATTN_MOE,),
    num_experts=16,
    experts_per_token=4,
    rope_theta=500_000.0,
    norm_type="layernorm",
    act="silu",
    gated_mlp=True,
)
