"""xLSTM-350M [ssm] — sLSTM + mLSTM residual blocks, ratio 7:1 (xLSTM[7:1]).

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304. [arXiv:2405.04517]

No attention, no positional embedding (recurrence is position-aware); decode
state is O(1) per layer ⇒ long_500k runs. The mLSTM uses the chunkwise-parallel
formulation (TPU adaptation — see DESIGN.md §3); sLSTM remains a lax.scan since
its state nonlinearity is inherently sequential (per the xLSTM paper).
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                     # xLSTM blocks embed their own up/down projections
    vocab_size=50304,
    pattern=(MLSTM,) * 7 + (SLSTM,),   # 24 = 3 × (7 mLSTM + 1 sLSTM)
    pos_emb="none",
    norm_type="layernorm",
    act="gelu",
    gated_mlp=False,
    mlstm_proj_factor=2.0,
    mlstm_chunk=256,
    slstm_heads=4,
    tie_embeddings=True,
)
