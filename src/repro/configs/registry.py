"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from repro.configs import (
    chameleon_34b,
    chatglm3_6b,
    dbrx_132b,
    granite_3_2b,
    mistral_nemo_12b,
    mixtral_8x22b,
    musicgen_large,
    qwen2_5_3b,
    recurrentgemma_9b,
    xlstm_350m,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen2_5_3b.CONFIG,
        chatglm3_6b.CONFIG,
        granite_3_2b.CONFIG,
        mistral_nemo_12b.CONFIG,
        musicgen_large.CONFIG,
        mixtral_8x22b.CONFIG,
        dbrx_132b.CONFIG,
        xlstm_350m.CONFIG,
        chameleon_34b.CONFIG,
        recurrentgemma_9b.CONFIG,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_active(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch × shape) is a runnable dry-run cell, with reason if not.

    long_500k needs sub-quadratic attention / bounded decode state; pure
    full-attention archs skip it (documented in DESIGN.md §5).
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full quadratic attention: unbounded 500k KV cache (see DESIGN.md §5)"
    return True, ""


def all_cells(include_skipped: bool = False):
    """Yield (arch_cfg, shape_cfg, active, reason) for the full 40-cell grid."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            active, reason = cell_is_active(arch, shape)
            if active or include_skipped:
                yield arch, shape, active, reason
