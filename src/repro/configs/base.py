"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; reduced "smoke"
variants (same family, tiny dims) are derived via ``ModelConfig.reduced()`` so
CPU tests exercise the same code paths the full configs lower through.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Block kinds understood by models/transformer.py
ATTN = "attn"                # full causal self-attention + MLP
ATTN_MOE = "attn_moe"        # attention + MoE FFN
RECURRENT = "recurrent"      # RG-LRU temporal mixing + MLP (Griffin residual block)
LOCAL_ATTN = "local_attn"    # sliding/local-window attention + MLP
MLSTM = "mlstm"              # xLSTM matrix-memory block
SLSTM = "slstm"              # xLSTM scalar-memory block

VOCAB_PAD_MULTIPLE = 16 * 8  # pad vocab so 16-way TP stays aligned to 8 sublanes


def pad_vocab(v: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return int(math.ceil(v / multiple) * multiple)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads

    # ---- block pattern -------------------------------------------------
    # Layer stack = `pattern` repeated; a trailing partial period is allowed
    # (e.g. recurrentgemma: 38 = 12*(R,R,A) + (R,R)).
    pattern: Tuple[str, ...] = (ATTN,)

    # ---- attention flavour ---------------------------------------------
    attn_bias: bool = False          # qwen-style QKV bias
    qk_norm: bool = False            # chameleon-style per-head RMS norm of q,k
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # chatglm "2d rope": rotary on a fraction of hd
    pos_emb: str = "rope"            # rope | sinusoidal | none
    sliding_window: Optional[int] = None   # SWA window (mixtral); None = full
    local_window: Optional[int] = None     # local-attn window (recurrentgemma)
    logit_softcap: float = 0.0

    # ---- MoE -------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2
    moe_group_size: int = 512        # tokens per dispatch group (memory control)

    # ---- recurrent / xLSTM ----------------------------------------------
    rglru_dim: int = 0               # RG-LRU recurrence width (0 → d_model)
    conv1d_width: int = 4
    mlstm_proj_factor: float = 2.0   # mLSTM up-projection factor
    mlstm_chunk: int = 256           # chunkwise-parallel chunk length
    slstm_heads: int = 4

    # ---- norms / act / embeddings -----------------------------------------
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"                # silu (gated) | gelu (non-gated)
    gated_mlp: bool = True
    tie_embeddings: bool = False
    mlp_bias: bool = False

    # ---- modality frontend -------------------------------------------------
    modality: str = "text"           # text | audio_frames | vlm_tokens
    # audio_frames: input_specs supplies [B, S, d_model] precomputed frame
    # embeddings (EnCodec frontend stub); vlm_tokens: early-fusion VQ tokens
    # share the text vocab so plain token ids are the native input.

    # ---- sizes ----------------------------------------------------------------
    max_seq: int = 131072
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ---- execution flags (perf knobs; see EXPERIMENTS.md §Perf) -------------
    use_pallas: bool = False         # True on real TPU; dry-run uses the XLA path
    decode_block_w: int = 256        # decode-attention KV block (serving engine
                                     # rounds cache capacity up to this so the
                                     # kernel never re-pads the cache per step)
    remat_policy: str = "full"       # none | minimal | full  (§Perf knob)
    scan_layers: bool = True
    bf16_reduce: bool = False        # §Perf: bf16 cross-device partial sums
                                     # (halves TP/FSDP all-reduce volume at a
                                     # documented precision trade)

    # ------------------------------------------------------------------ utils
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0 or self.num_kv_heads == 0

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer block kinds, length == num_layers."""
        reps = -(-self.num_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.num_layers])

    @property
    def num_scan_groups(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        """Layers past the last full pattern period (unscanned)."""
        return self.layer_kinds[self.num_scan_groups * len(self.pattern):]

    @property
    def has_attention(self) -> bool:
        return any(k in (ATTN, ATTN_MOE, LOCAL_ATTN) for k in self.layer_kinds)

    @property
    def is_subquadratic(self) -> bool:
        """True iff decode state is O(window)/O(1) — gates long_500k."""
        for k in self.layer_kinds:
            if k in (ATTN, ATTN_MOE) and self.sliding_window is None:
                return False
        return True

    @property
    def attn_window(self) -> Optional[int]:
        """KV-cache bound for attention layers (None = unbounded/full)."""
        if self.sliding_window is not None:
            return self.sliding_window
        if all(k in (RECURRENT, LOCAL_ATTN, MLSTM, SLSTM) for k in self.layer_kinds):
            return self.local_window
        return None

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs and sanity checks)."""
        d, hd, H, K = self.d_model, self.head_dim, self.num_heads, self.num_kv_heads
        n = self.padded_vocab * d                 # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d            # unembed
        for kind in self.layer_kinds:
            if kind in (ATTN, ATTN_MOE, LOCAL_ATTN):
                n += d * H * hd + 2 * d * K * hd + H * hd * d   # q, kv, o
                n += 2 * d                                       # norms
                if kind == ATTN_MOE:
                    mult = 3 if self.gated_mlp else 2
                    n += self.num_experts * (mult * d * self.d_ff)
                    n += d * self.num_experts                    # router
                else:
                    mult = 3 if self.gated_mlp else 2
                    n += mult * d * self.d_ff
            elif kind == RECURRENT:
                r = self.rglru_dim or d
                n += 2 * d * r + r * d            # in-proj(x2), out-proj
                n += r * self.conv1d_width + 2 * r  # conv + gates (diag-ish)
                mult = 3 if self.gated_mlp else 2
                n += mult * d * self.d_ff + 2 * d
            elif kind == MLSTM:
                f = int(self.mlstm_proj_factor * d)
                n += 2 * d * f + f * d            # up(x2), down
                n += 3 * f * f // 1               # qkv inside (approx, per-block)
                n += d
            elif kind == SLSTM:
                n += 4 * d * d + d * d + 2 * d    # ifzo gates + out
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        n = self.param_count()
        mult = 3 if self.gated_mlp else 2
        per_expert = mult * self.d_model * self.d_ff
        n_moe_layers = sum(1 for k in self.layer_kinds if k == ATTN_MOE)
        n -= n_moe_layers * (self.num_experts - self.experts_per_token) * per_expert
        return int(n)

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        base = dict(
            num_layers=max(2 * len(self.pattern), 2) if len(self.pattern) > 1 else 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            max_seq=512,
            moe_group_size=32,
            mlstm_chunk=16,
            sliding_window=16 if self.sliding_window else None,
            local_window=16 if self.local_window else None,
            rglru_dim=64 if self.rglru_dim else 0,
            name=self.name + "-smoke",
        )
        if len(self.pattern) > 1:
            # one full period (scanned) + the arch's tail remainder (unscanned)
            base["num_layers"] = len(self.pattern) + len(self.tail_kinds)
        if self.num_experts:
            base["num_experts"] = 4
            base["experts_per_token"] = min(self.experts_per_token, 2)
            # drop-free capacity so tiny-config tests are exactly deterministic
            # regardless of token grouping (full configs keep the real factor)
            base["capacity_factor"] = 4.0
        base.update(over)
        return dataclasses.replace(self, **base)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len × global_batch)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str                        # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}
