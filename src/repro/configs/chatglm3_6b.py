"""ChatGLM3-6B [dense] — GQA (kv=2), 2d/partial RoPE (rotary on half the head dim).

28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024. [arXiv:2406.12793]
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    pattern=(ATTN,),
    rotary_pct=0.5,            # ChatGLM applies rotary to half of each head dim
    attn_bias=True,            # GLM uses bias on QKV
    rope_theta=10000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
)
