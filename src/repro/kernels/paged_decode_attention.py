"""Pallas TPU flash-decode over a PAGED KV cache (block-table gather).

One query token per sequence attends to K/V scattered across fixed-size
pages of a shared pool (serving/kvpool.py). Same (B, nw) grid and VMEM
online-softmax scratch as ``kernels/decode_attention``, but the KV BlockSpec
index maps through the *scalar-prefetched block table*: grid step (b, wi)
DMAs pool page ``bt[b, wi]`` instead of slice ``wi`` of a dense per-slot
cache — the gather costs nothing extra because the pages-to-VMEM DMA was
happening anyway; only the page index changes. ``cache_len`` also arrives
via scalar prefetch for on-core validity masks.

Full (non-windowed) attention only: the serving engine gates paged mode to
archs whose KV is position-causal, hence page-shareable.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(clen_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, page_size: int, nw: int, G: int, scale: float):
    b = pl.program_id(0)
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # [K*G, hd] (heads-major)
    k = k_ref[0]                                     # [ps, K, hd] (one page)
    v = v_ref[0]
    ps, K, hd = k.shape
    qg = q.reshape(K, G, hd)
    # scores [K, G, ps]
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32) * scale

    clen = clen_ref[b]
    pos = wi * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)[0]
    valid = pos < clen + 1                           # new token already written
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_scr[...]                              # [K, G]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])                # [K, G, ps]
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)

    @pl.when(wi == nw - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(K * G, hd).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_tables, cache_len, *,
                           q_per_kv: int, interpret: bool = True):
    """q [B,1,H,hd]; pools [P, page_size, K, hd]; block_tables [B, nw] int32;
    cache_len scalar or [B] int32 (the new token's K/V must already be
    written at position ``cache_len`` through the block table)."""
    P, ps, K, hd = k_pool.shape
    B = q.shape[0]
    H = q.shape[2]
    G = q_per_kv
    nw = block_tables.shape[1]
    clen = jnp.asarray(cache_len, jnp.int32)
    if clen.ndim == 0:
        clen = jnp.broadcast_to(clen, (B,))
    bt = jnp.asarray(block_tables, jnp.int32)
    qf = q.reshape(B, H, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # cache_len, block table
        grid=(B, nw),
        in_specs=[
            pl.BlockSpec((1, H, hd),
                         lambda b, wi, clen_ref, bt_ref: (b, 0, 0)),
            # the paged gather: page index comes from the prefetched table
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, wi, clen_ref, bt_ref: (bt_ref[b, wi], 0, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, wi, clen_ref, bt_ref: (bt_ref[b, wi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd),
                               lambda b, wi, clen_ref, bt_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page_size=ps, nw=nw, G=G,
                          scale=1.0 / math.sqrt(hd)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(clen, bt, qf, k_pool, v_pool)
    return out[:, None]
