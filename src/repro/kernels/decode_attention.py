"""Pallas TPU flash-decode: one query token vs a (ring) KV cache.

Grid (B, nw): the window axis is innermost/arbitrary with (m, l, acc) VMEM
scratch carried across KV blocks. ``cache_len`` arrives via scalar prefetch
(PrefetchScalarGridSpec) so validity masks are computed on-core without a
host round-trip. GQA is native: no KV repetition — q is [K, G, hd] and each
KV block is [bw, K, hd].
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(clen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_w: int, nw: int, W: int, window, scale, G: int):
    b = pl.program_id(0)
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # [K*G, hd] (heads-major)
    k = k_ref[0]                                     # [bw, K, hd]
    v = v_ref[0]
    bw, K, hd = k.shape
    qg = q.reshape(K, G, hd)
    # scores [K, G, bw]
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32) * scale

    clen = clen_ref[b]
    pos = wi * block_w + jax.lax.broadcasted_iota(jnp.int32, (1, bw), 1)[0]
    n_valid = jnp.minimum(clen + 1, W)
    valid = pos < n_valid
    if window is not None:
        age = (clen % W) - pos
        age = jnp.where(age < 0, age + W, age)
        valid &= age < jnp.minimum(window, n_valid + 1)
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_scr[...]                              # [K, G]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])                # [K, G, bw]
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    # acc [K, G, hd] += p @ v  (batched over K)
    acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)

    @pl.when(wi == nw - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(K * G, hd).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, q_per_kv: int,
                     window: Optional[int] = None, block_w: int = 256,
                     interpret: bool = True):
    """q [B,1,H,hd]; caches [B,W,K,hd]; cache_len scalar or [B] int32."""
    B, W, K, hd = k_cache.shape
    H = q.shape[2]
    G = q_per_kv
    block_w = min(block_w, W)
    Wp = -(-W // block_w) * block_w
    if Wp == W:
        # capacity already block-aligned (the serving engine rounds it up):
        # no per-step copy of the whole cache
        kp, vp = k_cache, v_cache
    else:
        kp = jnp.pad(k_cache, ((0, 0), (0, Wp - W), (0, 0), (0, 0)))
        vp = jnp.pad(v_cache, ((0, 0), (0, Wp - W), (0, 0), (0, 0)))
    nw = Wp // block_w
    clen = jnp.asarray(cache_len, jnp.int32)
    if clen.ndim == 0:
        clen = jnp.broadcast_to(clen, (B,))
    qf = q.reshape(B, H, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nw),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, wi, clen_ref: (b, 0, 0)),
            pl.BlockSpec((1, block_w, K, hd), lambda b, wi, clen_ref: (b, wi, 0, 0)),
            pl.BlockSpec((1, block_w, K, hd), lambda b, wi, clen_ref: (b, wi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, wi, clen_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_w=block_w, nw=nw, W=W, window=window,
                          scale=1.0 / math.sqrt(hd), G=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(clen, qf, kp, vp)
    return out[:, None]
