"""Pallas TPU flash attention (causal + GQA-repeated + sliding window).

Grid (B, H, nq, nk): outer three parallel, innermost arbitrary — the (m, l,
acc) online-softmax state lives in VMEM scratch and is carried across the nk
iterations for each q block. Block shapes are MXU-aligned (bq × hd, bkv × hd,
multiples of 128 on the lane dim); K/V stream HBM→VMEM one block per step.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_kv: int, nk: int, window, scale, seq_t: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                   # [bq, hd]
    k = k_ref[0, 0]                                   # [bkv, hd]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    mask &= k_pos < seq_t
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """q,k,v: [B, S(T), H, hd] (KV already repeated to H heads). Causal."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    Sp = -(-S // block_q) * block_q
    Tp = -(-T // block_kv) * block_kv
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    # layout [B, H, S, hd] so blocks are contiguous (lane dim = hd)
    qt = jnp.moveaxis(qp, 2, 1)
    kt = jnp.moveaxis(kp, 2, 1)
    vt = jnp.moveaxis(vp, 2, 1)
    nq, nk = Sp // block_q, Tp // block_kv
    grid = (B, H, nq, nk)
    scale = 1.0 / math.sqrt(hd)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_kv=block_kv, nk=nk,
                          window=window, scale=scale, seq_t=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)[:, :S]
