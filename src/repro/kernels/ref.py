"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are intentionally naive — full score matrices, step-by-step recurrences
— so the kernels (and the blocked XLA paths in models/) can be asserted
against simple, obviously-correct math.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_positions=None):
    """Naive full attention. q,k,v: [B,S,H,hd] / [B,T,H,hd]."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    q_pos = (jnp.arange(S) if q_positions is None else q_positions).astype(jnp.int32)
    k_pos = jnp.arange(T)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, q_per_kv: int,
                     window: Optional[int] = None):
    """Naive single-token GQA decode over a ring cache. q [B,1,H,hd]."""
    B, W, K, hd = k_cache.shape
    H = q.shape[2]
    qg = q.reshape(B, K, q_per_kv, hd)
    s = jnp.einsum("bkgh,bwkh->bkgw", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos = jnp.arange(W)
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        clen = clen[None]
    n_valid = jnp.minimum(clen + 1, W)
    valid = pos[None, :] < n_valid[:, None]
    if window is not None:
        age = (clen % W)[:, None] - pos[None, :]
        age = jnp.where(age < 0, age + W, age)
        valid &= age < jnp.minimum(window, n_valid + 1)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def rglru_scan(a, bx, h0=None):
    """Sequential linear recurrence h_t = a_t*h_{t-1} + bx_t over [B,S,R]."""
    B, S, R = a.shape
    h = jnp.zeros((B, R), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    out = []
    hs = h
    def step(h, xs):
        a_t, b_t = xs
        h = a_t * h + b_t
        return h, h
    h_last, ys = jax.lax.scan(step, h, (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
                                        jnp.moveaxis(bx.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h_last


def mlstm(q, k, v, ig, fg, state=None):
    """Fully sequential stabilized mLSTM (one step at a time)."""
    B, S, H, hd = q.shape
    if state is None:
        C = jnp.zeros((B, H, hd, hd), jnp.float32)
        n = jnp.zeros((B, H, hd), jnp.float32)
        m = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C, n, m = state
    scale = hd ** -0.5
    outs = []
    for t in range(S):
        qt = q[:, t].astype(jnp.float32) * scale
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        logf = jax.nn.log_sigmoid(fg[:, t].astype(jnp.float32))
        it = ig[:, t].astype(jnp.float32)
        m_new = jnp.maximum(logf + m, it)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(it - m_new)
        C = C * fp[..., None, None] + ip[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.sum(qt * n, axis=-1)), jnp.exp(-m_new))
        outs.append((num / den[..., None]).astype(q.dtype))
        m = m_new
    return jnp.stack(outs, axis=1), (C, n, m)
