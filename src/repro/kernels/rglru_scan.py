"""Pallas TPU RG-LRU scan: time-blocked sequential linear recurrence.

Grid (B, nR, nT): nT innermost/arbitrary; the hidden state h [1, bR] persists
in VMEM scratch across time blocks (channels are independent → the R axis is
embarrassingly parallel and tiles the lane dimension). Inside a block the
recurrence is an unrolled loop of vector ops over [bT, bR] in VMEM — the TPU
replacement for the GPU per-timestep kernel (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, bx_ref, y_ref, h_scr, *, block_t: int, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)                  # [bT, bR]
    bx = bx_ref[0].astype(jnp.float32)
    h = h_scr[...]                                    # [1, bR]

    def step(i, carry):
        h, ys = carry
        h = a[i][None, :] * h + bx[i][None, :]
        ys = jax.lax.dynamic_update_slice(ys, h, (i, 0))
        return h, ys

    ys = jnp.zeros_like(a)
    h, ys = jax.lax.fori_loop(0, block_t, step, (h, ys))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def rglru_scan(a, bx, *, block_t: int = 128, block_r: int = 128,
               interpret: bool = True):
    """h_t = a_t ⊙ h_{t-1} + bx_t over [B, S, R]. Returns (y, h_last)."""
    B, S, R = a.shape
    block_t = min(block_t, S)
    block_r = min(block_r, R)
    Sp = -(-S // block_t) * block_t
    Rp = -(-R // block_r) * block_r
    ap = jnp.pad(a, ((0, 0), (0, Sp - S), (0, Rp - R)))
    bp = jnp.pad(bx, ((0, 0), (0, Sp - S), (0, Rp - R)))
    nt, nr = Sp // block_t, Rp // block_r

    y = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, nt=nt),
        grid=(B, nr, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_r), lambda b, ri, ti: (b, ti, ri)),
            pl.BlockSpec((1, block_t, block_r), lambda b, ri, ti: (b, ti, ri)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_r), lambda b, ri, ti: (b, ti, ri)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Rp), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_r), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    y = y[:, :S, :R]
    return y, y[:, -1].astype(jnp.float32)
