"""Pallas TPU chunkwise mLSTM: matrix-memory recurrence, one chunk per step.

Grid (B, H, nc): nc innermost/arbitrary; the (C [hd,hd], n [hd], m [1])
state persists in VMEM scratch across chunks. Each step does the
attention-like intra-chunk matmuls (MXU) + the inter-chunk state update —
the TPU-native replacement for the xLSTM CUDA step kernel (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref, h_ref,
            c_scr, n_scr, m_scr, *, L: int, nc: int, scale: float):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [L, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = ig_ref[0, 0].astype(jnp.float32)              # [L, 1]
    fg = fg_ref[0, 0].astype(jnp.float32)
    C = c_scr[...]
    n = n_scr[...]                                     # [1, hd]
    m = m_scr[0, 0]

    logf = jax.nn.log_sigmoid(fg)                      # [L, 1]
    F = jnp.cumsum(logf, axis=0)                       # [L, 1]
    FL = F[L - 1, 0]
    # intra-chunk pair weights
    logD = F - F.T + ig.T                              # [L(j), L(i)]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    logD = jnp.where(tri, logD, NEG_INF)
    m_intra = jnp.max(logD, axis=1, keepdims=True)     # [L, 1]
    m_inter = F + m
    mj = jnp.maximum(m_inter, m_intra)
    d = jnp.exp(logD - mj)
    inter = jnp.exp(m_inter - mj)                      # [L, 1]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    w = s * d
    h_intra = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_inter = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_num = h_inter * inter + h_intra
    n_j = inter * n + jax.lax.dot_general(d, k, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32)
    denom = jnp.maximum(jnp.abs(jnp.sum(q * n_j, axis=1, keepdims=True)),
                        jnp.exp(-mj))
    h_ref[0, 0] = (h_num / denom).astype(h_ref.dtype)

    # ---- state to end of chunk ------------------------------------------
    m_next = jnp.maximum(FL + m, jnp.max(FL - F + ig))
    sc = jnp.exp(FL - F + ig - m_next)                 # [L, 1]
    decay = jnp.exp(FL + m - m_next)
    c_scr[...] = C * decay + jax.lax.dot_general(
        k * sc, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_scr[...] = n * decay + jnp.sum(k * sc, axis=0, keepdims=True)
    m_scr[0, 0] = m_next


def mlstm_chunk(q, k, v, ig, fg, *, chunk: int = 128, interpret: bool = True):
    """Chunkwise mLSTM. q,k,v [B,S,H,hd]; ig,fg [B,S,H]. Returns h [B,S,H,hd]."""
    B, S, H, hd = q.shape
    L = min(chunk, S)
    Sp = -(-S // L) * L
    if Sp != S:
        pad4 = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = (jnp.pad(x, pad4) for x in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, Sp - S), (0, 0)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, Sp - S), (0, 0)), constant_values=30.0)
    nc = Sp // L
    # layouts: [B, H, S, hd] and [B, H, S, 1]
    qt, kt, vt = (jnp.moveaxis(x, 2, 1) for x in (q, k, v))
    igt = jnp.moveaxis(ig, 2, 1)[..., None]
    fgt = jnp.moveaxis(fg, 2, 1)[..., None]

    h = pl.pallas_call(
        functools.partial(_kernel, L=L, nc=nc, scale=hd ** -0.5),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, ci: (b, h, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, hd), lambda b, h, ci: (b, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, igt, fgt)
    return jnp.moveaxis(h, 1, 2)[:, :S]
