"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True in this CPU container (the kernels execute via
the Pallas interpreter for validation); on real TPU pass interpret=False —
`ModelConfig.use_pallas` routes the model layer here.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import mlstm_chunk as _mc
from repro.kernels import paged_decode_attention as _pda
from repro.kernels import rglru_scan as _rg

flash_attention = functools.partial(_fa.flash_attention)
decode_attention = functools.partial(_da.decode_attention)
paged_decode_attention = functools.partial(_pda.paged_decode_attention)
rglru_scan = functools.partial(_rg.rglru_scan)
mlstm_chunk = functools.partial(_mc.mlstm_chunk)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_kv",
                                             "interpret"))
def flash_attention_jit(q, k, v, *, window=None, block_q=128, block_kv=128,
                        interpret=True):
    return _fa.flash_attention(q, k, v, window=window, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("q_per_kv", "window", "block_w",
                                             "interpret"))
def decode_attention_jit(q, k_cache, v_cache, cache_len, *, q_per_kv,
                         window=None, block_w=256, interpret=True):
    return _da.decode_attention(q, k_cache, v_cache, cache_len,
                                q_per_kv=q_per_kv, window=window,
                                block_w=block_w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("q_per_kv", "interpret"))
def paged_decode_attention_jit(q, k_pool, v_pool, block_tables, cache_len, *,
                               q_per_kv, interpret=True):
    return _pda.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                       cache_len, q_per_kv=q_per_kv,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_t", "block_r", "interpret"))
def rglru_scan_jit(a, bx, *, block_t=128, block_r=128, interpret=True):
    return _rg.rglru_scan(a, bx, block_t=block_t, block_r=block_r,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_jit(q, k, v, ig, fg, *, chunk=128, interpret=True):
    return _mc.mlstm_chunk(q, k, v, ig, fg, chunk=chunk, interpret=interpret)
