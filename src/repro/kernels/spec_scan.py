"""Pallas TPU fused accept-length scan for speculative decoding.

The verify step of drafter-free speculative decode (serving/spec.py,
sampler.accept_batched) reduces per-row accept flags to the length of the
accepted draft prefix: ``m[b] = #leading True in accept[b, :draft_lens[b]]``.
XLA lowers the naive formulation as a where + min-reduce pair with an int32
temp per element; this kernel fuses flag masking and the reduction into one
VMEM pass so the (tiny but per-engine-step) scan never round-trips through
HBM. One grid step — B × spec_len is far below a single VMEM tile.

``interpret`` defaults to True in this CPU container (Pallas interpreter);
pass interpret=False on real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(acc_ref, len_ref, m_ref, *, S: int):
    acc = acc_ref[...]                               # [B, S] int32 (1 = accept)
    lens = len_ref[...]                              # [B, 1] int32
    col = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
    # first rejected draft position (S when the whole draft is accepted)
    bad = (acc == 0) & (col < lens)
    first_bad = jnp.min(jnp.where(bad, col, S), axis=1, keepdims=True)
    m_ref[...] = jnp.minimum(first_bad, lens)


def accept_len(accept, draft_lens, *, interpret: bool = True):
    """accept [B, S] bool, draft_lens [B] int32 -> accepted prefix length [B].

    Column i of ``accept`` is the accept flag of draft token i; columns at or
    past ``draft_lens[b]`` are padding and ignored.
    """
    B, S = accept.shape
    acc = accept.astype(jnp.int32)
    lens = draft_lens.astype(jnp.int32).reshape(B, 1)
    m = pl.pallas_call(
        functools.partial(_kernel, S=S),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(acc, lens)
    return m[:, 0]


def accept_len_ref(accept, draft_lens):
    """Pure-XLA reference (also the CPU serving path in sampler.py)."""
    S = accept.shape[1]
    col = jnp.arange(S, dtype=jnp.int32)[None, :]
    bad = (~accept) & (col < draft_lens[:, None])
    first_bad = jnp.min(jnp.where(bad, col, S), axis=1)
    return jnp.minimum(first_bad, draft_lens)
