"""LangGraph-like agent graph runtime (§2 Agentic Frameworks).

Nodes are functions over a shared mutable state dict; edges connect them,
conditional edges route on a predicate; execution runs supersteps until END
or the LangGraph default limit (25). Each FAME agent (Planner / Actor /
Evaluator) is one small graph executed inside one FaaS function invocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

END = "__end__"
START = "__start__"

SUPERSTEP_LIMIT = 25      # LangGraph's default recursion limit


class GraphRecursionError(RuntimeError):
    pass


@dataclasses.dataclass
class AgentGraph:
    name: str
    nodes: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    edges: Dict[str, str] = dataclasses.field(default_factory=dict)
    cond_edges: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    entry: Optional[str] = None

    def add_node(self, name: str, fn: Callable):
        self.nodes[name] = fn
        if self.entry is None:
            self.entry = name
        return self

    def add_edge(self, src: str, dst: str):
        if src == START:
            self.entry = dst
        else:
            self.edges[src] = dst
        return self

    def add_conditional_edge(self, src: str, router: Callable):
        """router(state) -> next node name (or END)."""
        self.cond_edges[src] = router
        return self

    def run(self, state: Dict[str, Any], ctx=None) -> Dict[str, Any]:
        node = self.entry
        steps = 0
        while node != END:
            if node is None or node not in self.nodes:
                raise KeyError(f"graph {self.name}: missing node {node!r}")
            steps += 1
            if steps > SUPERSTEP_LIMIT:
                raise GraphRecursionError(
                    f"graph {self.name} exceeded {SUPERSTEP_LIMIT} supersteps")
            updates = self.nodes[node](state, ctx) or {}
            state.update(updates)
            if node in self.cond_edges:
                node = self.cond_edges[node](state)
            else:
                node = self.edges.get(node, END)
        return state
