"""Step-Functions-style workflow orchestration (§3.1).

A state machine of Task / Choice / Succeed / Fail states executed over the
FaaS platform, with per-state retry policies (exponential backoff) and the
ReAct cycle: Planner → Actor → Evaluator → (Choice) → Succeed | Planner.
Per-transition billing matches the Step Functions pricing model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.pricing import PRICING
from repro.core.telemetry import emit


@dataclasses.dataclass
class Retry:
    max_attempts: int = 2
    backoff_s: float = 1.0
    backoff_rate: float = 2.0


@dataclasses.dataclass
class TaskState:
    name: str
    function: str                       # FaaS function name
    next: Optional[str] = None
    retry: Retry = dataclasses.field(default_factory=Retry)


@dataclasses.dataclass
class ChoiceState:
    name: str
    router: Callable[[dict], str]       # payload -> next state name


@dataclasses.dataclass
class SucceedState:
    name: str = "Succeed"


@dataclasses.dataclass
class FailState:
    name: str = "Fail"
    error: str = "WorkflowFailed"


class StateMachine:
    def __init__(self, name: str, platform, states: List[Any], start: str):
        self.name = name
        self.platform = platform
        self.states = {s.name: s for s in states}
        self.start = start

    def execute(self, payload: dict, t: float = 0.0):
        """Run to completion. Returns (payload, t_end, status)."""
        state_name = self.start
        transitions = 0
        t0 = t
        while True:
            state = self.states[state_name]
            transitions += 1
            if isinstance(state, SucceedState):
                status = "SUCCEEDED"
                break
            if isinstance(state, FailState):
                status = "FAILED"
                break
            if isinstance(state, ChoiceState):
                state_name = state.router(payload)
                continue
            # TaskState with retry policy
            attempt, backoff = 0, state.retry.backoff_s
            while True:
                try:
                    payload, t = self.platform.invoke(state.function, payload, t)
                    break
                except Exception:  # noqa: BLE001 — retry per policy, then DLQ
                    attempt += 1
                    if attempt > state.retry.max_attempts:
                        emit("workflow", f"{self.name}:{state.name}", t0, t,
                             dlq=True, cost_cents=transitions * PRICING.stepfn_transition_cents)
                        return payload, t, "FAILED"
                    t += backoff
                    backoff *= state.retry.backoff_rate
            state_name = state.next
        cost = transitions * PRICING.stepfn_transition_cents
        emit("workflow", self.name, t0, t, transitions=transitions,
             cost_cents=cost, status=status)
        return payload, t, status


def build_react_machine(platform, *, planner_fn: str, actor_fn: str,
                        evaluator_fn: str, max_iterations: int = 3) -> StateMachine:
    """The cyclic ReAct workflow of Fig. 2."""

    def route(payload: dict) -> str:
        verdict = payload.get("verdict", {})
        if verdict.get("success"):
            return "Succeed"
        if verdict.get("needs_retry") and payload.get("iteration", 1) < max_iterations:
            payload["iteration"] = payload.get("iteration", 1) + 1
            return "Planner"
        return "Fail"

    return StateMachine(
        "fame-react", platform,
        states=[
            TaskState("Planner", planner_fn, next="Actor"),
            TaskState("Actor", actor_fn, next="Evaluator"),
            TaskState("Evaluator", evaluator_fn, next="Decide"),
            ChoiceState("Decide", route),
            SucceedState(),
            FailState(),
        ],
        start="Planner")
