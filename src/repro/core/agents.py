"""The ReAct agents (§3.1): Planner, Actor, Evaluator as FaaS handlers.

Each agent is a small LangGraph (`agent_graph.AgentGraph`) executed inside one
FaaS function invocation; state flows between agents as Step-Function
messages. System prompts are the paper's (Appendix A.1).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.core.agent_graph import END, START, AgentGraph
from repro.core.mcp import rpc_call, rpc_tools_list
from repro.core.memory import MemoryEntry

PLANNER_PROMPT = """\
You are a planner agent. Based on the user's query and available tools, generate a
plan that specifies WHICH TOOLS to use and the SEQUENCE of tool calls.
- Available tools:
{tools_description}
- Return ONLY valid JSON with this structure:
{{"tools_to_use": [ ... ], "reasoning": "Brief explanation of the plan"}}
"""

ACTOR_PROMPT = """\
Based on this plan, execute the specified tools to address the user's query.
- Plan: {plan_json}
Execute the tools in the sequence specified by the plan. Let the tools help you
solve the query.
"""

# §4.2 prompt engineering: make the Actor reuse memory instead of re-calling.
ACTOR_MEMORY_PROMPT = """\
Check previous ToolMessage responses in conversation history before making new
tool calls. Extract data from previous tool outputs instead of calling tools
again with the same parameters. Only make new calls if data is unavailable or
parameters differ.
"""

EVALUATOR_PROMPT = """\
Evaluate if this action successfully addressed the user query:
- Plan: {plan_json}
- Result: {result_json}
- Current Iteration: {iteration_count}/{max_iterations}
- Respond with ONLY valid JSON:
{{"success": bool, "needs_retry": bool, "reason": "Brief explanation",
  "feedback": "If needs_retry=true, provide feedback ..."}}
Notes:
- Set success=true if the action result successfully answers the user query
- Set needs_retry=true if you think another iteration with a different plan would
- Only set needs_retry=true if iteration_count less than max_iterations
- If iteration_count >= max_iterations, set needs_retry=false
- feedback field is only required if needs_retry=true
"""


def render_messages(messages: List[Dict[str, Any]]) -> str:
    out = []
    for m in messages:
        role = m.get("role", "?")
        if role == "tool":
            out.append(f"[ToolMessage tool={m.get('tool')} args="
                       f"{json.dumps(m.get('arguments', {}), sort_keys=True)}]\n"
                       f"{m.get('content', '')}")
        else:
            out.append(f"[{role}] {m.get('content', '')}")
    return "\n".join(out)


def _context(payload: dict, extra: str = "") -> str:
    """Assemble the visible context string for an agent LLM call."""
    parts = []
    if payload.get("client_history"):
        parts.append("[CLIENT HISTORY]\n" + payload["client_history"])
    if payload.get("memory_context"):
        parts.append(payload["memory_context"])
    if payload.get("feedback"):
        parts.append("[EVALUATOR FEEDBACK]\n" + payload["feedback"])
    parts.append("[USER REQUEST]\n" + payload.get("user_request", ""))
    if payload.get("messages"):
        parts.append("[MESSAGES]\n" + render_messages(payload["messages"]))
    if extra:
        parts.append(extra)
    return "\n\n".join(parts)


class ReActAgents:
    """Builds the three agent FaaS handlers bound to a FameRuntime."""

    def __init__(self, runtime):
        self.rt = runtime

    # ------------------------------------------------------------- Planner
    def planner_handler(self, payload: dict, ctx) -> dict:
        rt = self.rt
        # 1. memory bootstrapping (§3.2): inject prior session memory
        memory_context = ""
        if rt.config.agentic_memory:
            ctx.charge(0.012)                                  # DynamoDB query
            memory_context = rt.memory.render_context(
                payload["session_id"], t=ctx.now())
        # 2. query tool descriptions from every MCP server (§3.1)
        tool_descs = []
        for fn_name in rt.mcp_function_names():
            resp = ctx.invoke(fn_name, {"body": rpc_tools_list()})
            for t in resp["body"]["result"]["tools"]:
                tool_descs.append(f"- {t['name']}: {t['description']}")
        payload = dict(payload, memory_context=memory_context)

        graph = AgentGraph("planner")

        def llm_node(state, gctx):
            system = PLANNER_PROMPT.format(tools_description="\n".join(tool_descs))
            resp = rt.llm("planner").chat(system, _context(payload), ctx)
            return {"plan_json": resp.text}

        graph.add_node("llm", llm_node)
        graph.add_edge("llm", END)
        state = graph.run({}, ctx)
        messages = list(payload.get("messages", []))
        messages.append({"role": "planner", "content": state["plan_json"]})
        return dict(payload, plan_json=state["plan_json"], messages=messages,
                    memory_context=memory_context)

    # --------------------------------------------------------------- Actor
    def actor_handler(self, payload: dict, ctx) -> dict:
        rt = self.rt
        graph = AgentGraph("actor")
        system = ACTOR_PROMPT.format(plan_json=payload.get("plan_json", ""))
        if rt.config.agentic_memory:
            system += "\n" + ACTOR_MEMORY_PROMPT

        def llm_node(state, gctx):
            resp = rt.llm("actor").chat(system, _context(
                dict(payload, messages=state["messages"])), ctx)
            try:
                decision = json.loads(resp.text)
            except json.JSONDecodeError:
                decision = {"final": resp.text}
            return {"decision": decision}

        def route(state):
            return "tools" if state["decision"].get("tool_calls") else END

        def tool_node(state, gctx):
            messages = list(state["messages"])
            for call in state["decision"]["tool_calls"]:
                fn_name = rt.resolve_tool_function(call["tool"])
                resp = ctx.invoke(fn_name, {"body": rpc_call(
                    call["tool"], call.get("arguments", {}))})
                body = resp["body"]
                if "error" in body:
                    content = f"ERROR: {body['error']['message']}"
                else:
                    content = body["result"]["content"][0]["text"]
                messages.append({"role": "tool", "tool": call["tool"],
                                 "arguments": call.get("arguments", {}),
                                 "content": content})
            return {"messages": messages}

        graph.add_node("llm", llm_node)
        graph.add_node("tools", tool_node)
        graph.add_conditional_edge("llm", route)
        graph.add_edge("tools", "llm")
        state = graph.run({"messages": list(payload.get("messages", []))}, ctx)
        final = state["decision"].get("final", "")
        messages = state["messages"] + [{"role": "actor", "content": final}]
        return dict(payload, result_json=final, messages=messages)

    # ----------------------------------------------------------- Evaluator
    def evaluator_handler(self, payload: dict, ctx) -> dict:
        rt = self.rt
        system = EVALUATOR_PROMPT.format(
            plan_json=payload.get("plan_json", ""),
            result_json=payload.get("result_json", ""),
            iteration_count=payload.get("iteration", 1),
            max_iterations=payload.get("max_iterations", 3))
        resp = rt.llm("evaluator").chat(system, _context(payload), ctx)
        try:
            verdict = json.loads(resp.text)
        except json.JSONDecodeError:
            verdict = {"success": False, "needs_retry": False,
                       "reason": "unparseable evaluator output"}
        # §3.2: persist THIS invocation's memory delta before returning
        if rt.config.agentic_memory:
            ctx.charge(0.010)                                   # DynamoDB write
            rt.memory.persist(MemoryEntry(
                session_id=payload["session_id"],
                invocation_id=payload["invocation_id"],
                user_request=payload.get("user_request", ""),
                messages=payload.get("messages", []),
                final_response=payload.get("result_json", "")), t=ctx.now())
        return dict(payload, verdict=verdict,
                    feedback=verdict.get("feedback", ""))
