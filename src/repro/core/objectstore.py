"""S3-analogue object store: buckets, keys, metadata (TTL), URL handles.

Used by FAME for (a) the MCP invocation cache (§3.3.2), (b) S3-based file
handling — tools put large payloads here and pass ``s3://`` URLs instead of
inlining content into the agent context window.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import time
from typing import Any, Dict, Optional, Tuple

from repro.core.pricing import PRICING
from repro.core.telemetry import emit


@dataclasses.dataclass
class Obj:
    data: bytes
    metadata: Dict[str, Any]
    put_time: float


class ObjectStore:
    """In-process S3 semantics; deterministic; costs metered."""

    def __init__(self, clock=None):
        self._buckets: Dict[str, Dict[str, Obj]] = {}
        self.clock = clock           # FaaS clock provider (for TTLs); optional

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None else time.time()

    # ---- core API -------------------------------------------------------
    def put(self, bucket: str, key: str, data: bytes,
            metadata: Optional[Dict[str, Any]] = None, t: Optional[float] = None) -> str:
        b = self._buckets.setdefault(bucket, {})
        now = t if t is not None else self._now()
        b[key] = Obj(bytes(data), dict(metadata or {}), now)
        emit("store", f"s3:put:{bucket}", now, now, bytes=len(data),
             cost_cents=PRICING.s3_put_cents)
        return f"s3://{bucket}/{key}"

    def get(self, bucket: str, key: str, t: Optional[float] = None) -> Optional[Obj]:
        now = t if t is not None else self._now()
        obj = self._buckets.get(bucket, {}).get(key)
        emit("store", f"s3:get:{bucket}", now, now,
             bytes=len(obj.data) if obj else 0, cost_cents=PRICING.s3_get_cents,
             hit=obj is not None)
        if obj is None:
            return None
        ttl = obj.metadata.get("ttl_s")
        if ttl is not None and ttl >= 0 and now - obj.put_time > ttl:
            return None                      # stale per §3.3.2
        return obj

    def get_url(self, url: str, t: Optional[float] = None) -> Optional[Obj]:
        bucket, key = self.parse_url(url)
        return self.get(bucket, key, t)

    def delete(self, bucket: str, key: str):
        self._buckets.get(bucket, {}).pop(key, None)

    def list(self, bucket: str, pattern: str = "*"):
        return [k for k in self._buckets.get(bucket, {}) if fnmatch.fnmatch(k, pattern)]

    @staticmethod
    def parse_url(url: str) -> Tuple[str, str]:
        assert url.startswith("s3://"), url
        bucket, _, key = url[5:].partition("/")
        return bucket, key

    # ---- convenience: the file-handling library (§3.3.2) ----------------
    def stash(self, bucket: str, key: str, text: str, t: Optional[float] = None,
              **metadata) -> str:
        """Store large content, return a URL handle for the agent context."""
        return self.put(bucket, key, text.encode(), metadata, t=t)

    def fetch_text(self, url: str, t: Optional[float] = None) -> Optional[str]:
        obj = self.get_url(url, t)
        return obj.data.decode() if obj is not None else None
