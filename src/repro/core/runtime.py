"""FameRuntime: the assembled FAME stack (Fig. 2).

Wires the FaaS platform, object/KV stores, agent memory, MCP cache, LLM
backends, the three ReAct agent functions and the Step-Functions machine; and
runs multi-turn client sessions under any Table-1 memory configuration.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence

from repro.core import config as cfg_mod
from repro.core.agents import ReActAgents
from repro.core.faas import FaaSPlatform, FunctionDef
from repro.core.fusion import DeploymentPlan, plan_consolidated, plan_singleton
from repro.core.kvstore import KVStore
from repro.core.llm import LLMBackend, ScriptedOracle
from repro.core.memory import AgentMemory
from repro.core.objectstore import ObjectStore
from repro.core.telemetry import Trace, use_trace
from repro.core.toolcache import CacheManager
from repro.core.workflow import build_react_machine
from repro.core.wrapper import WrappedServer, wrap_server


@dataclasses.dataclass
class SessionResult:
    responses: List[str]
    statuses: List[str]
    traces: List[Trace]
    t_end: float

    @property
    def dnf(self) -> bool:
        return any(s != "SUCCEEDED" for s in self.statuses)


class FameRuntime:
    def __init__(self, *, config: cfg_mod.MemoryConfig,
                 llm_backends: Optional[Dict[str, LLMBackend]] = None,
                 fusion_mode: str = "singleton",
                 max_iterations: int = 3,
                 agent_memory_mb: int = 512):
        self.config = config
        self.platform = FaaSPlatform()
        self.objects = ObjectStore()
        self.kv = KVStore()
        self.memory = AgentMemory(self.kv, enabled=config.agentic_memory)
        self.cache = CacheManager(self.objects, enabled=config.mcp_caching)
        self.fusion_mode = fusion_mode
        self.max_iterations = max_iterations
        self._llms = llm_backends or {}
        self._default_llm = ScriptedOracle()
        self.mcp_plan: Optional[DeploymentPlan] = None
        self._wrapped: List[WrappedServer] = []
        self._invocation_counter = itertools.count(1)

        agents = ReActAgents(self)
        for name, handler in [("fame-planner", agents.planner_handler),
                              ("fame-actor", agents.actor_handler),
                              ("fame-evaluator", agents.evaluator_handler)]:
            self.platform.deploy(FunctionDef(name=name, handler=handler,
                                             memory_mb=agent_memory_mb,
                                             role="agent"))
        self.machine = build_react_machine(
            self.platform, planner_fn="fame-planner", actor_fn="fame-actor",
            evaluator_fn="fame-evaluator", max_iterations=max_iterations)

    # ---- LLM backends ------------------------------------------------------
    def llm(self, role: str) -> LLMBackend:
        return self._llms.get(role, self._default_llm)

    def set_llm(self, role: str, backend: LLMBackend):
        self._llms[role] = backend

    # ---- MCP deployment (§3.3) ---------------------------------------------
    def deploy_mcp(self, servers: Sequence, sources: Optional[Dict[str, str]] = None):
        """Wrap (FAME automation) + deploy per the fusion mode."""
        self._wrapped = [
            wrap_server(s, source=(sources or {}).get(s.name),
                        cache=self.cache, fame_runtime=self)
            for s in servers]
        if self.fusion_mode == "consolidated":
            self.mcp_plan = plan_consolidated(self._wrapped, "mcp-consolidated")
        else:
            self.mcp_plan = plan_singleton(self._wrapped)
        for fn in self.mcp_plan.functions:
            self.platform.deploy(fn)

    def mcp_function_names(self) -> List[str]:
        return [f.name for f in (self.mcp_plan.functions if self.mcp_plan else [])]

    def resolve_tool_function(self, tool: str) -> str:
        return self.mcp_plan.tool_to_function[tool]

    # ---- client sessions (multi-turn, §3.2 / Fig. 3) -------------------------
    def run_session(self, session_id: str, queries: Sequence[str],
                    t: float = 0.0) -> SessionResult:
        responses, statuses, traces = [], [], []
        client_history = ""
        for qi, query in enumerate(queries):
            invocation_id = f"inv{next(self._invocation_counter):04d}"
            payload = {
                "session_id": session_id,
                "invocation_id": invocation_id,
                "user_request": query,
                "iteration": 1,
                "max_iterations": self.max_iterations,
                "client_history": client_history if self.config.client_memory else "",
                "messages": [],
            }
            trace = Trace()
            with use_trace(trace):
                payload, t, status = self.machine.execute(payload, t)
            response = payload.get("result_json", "")
            responses.append(response)
            statuses.append(status)
            traces.append(trace)
            if self.config.client_memory:
                # naive cumulative transcript (config N and richer)
                client_history += f"\n[user] {query}\n[assistant] {response}"
        return SessionResult(responses, statuses, traces, t)
