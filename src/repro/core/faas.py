"""FaaS platform runtime (AWS-Lambda-like), on a deterministic simulated clock.

Function bodies are REAL Python callables; the platform models the serverless
control plane around them: micro-VM instance pools, cold starts, retention
reclaim, per-GB-ms billing, the 15-minute timeout, concurrency autoscaling and
straggler mitigation (speculative re-execution past a latency deadline).

Time model: ``invoke(fn, payload, t)`` executes the handler immediately in
wall time but advances *simulated* time by cold-start + declared/derived
handler durations (handlers charge work via ``Ctx.charge(seconds)``).
Recursive invokes compose causally; concurrent workloads (e.g. the §5.3.2
1-RPS consolidation experiment) share instance pools across chains.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional

from repro.core.pricing import PRICING
from repro.core.telemetry import emit


class FaaSTimeout(Exception):
    pass


@dataclasses.dataclass
class FunctionDef:
    name: str
    handler: Callable                       # handler(payload: dict, ctx: Ctx) -> dict
    memory_mb: int = 512
    timeout_s: float = 900.0                # the 15-minute Lambda cap (§3.1)
    cold_start_s: float = 1.2               # micro-VM boot + runtime import
    init_extra_s: float = 0.0               # package-size-dependent init (fusion!)
    retention_s: float = 600.0              # warm-container retention period
    role: str = "generic"                   # agent | mcp | generic (for billing split)


@dataclasses.dataclass
class _Instance:
    busy_until: float
    last_used: float


class Ctx:
    """Execution context passed to handlers."""

    def __init__(self, platform: "FaaSPlatform", fn: FunctionDef, t_start: float):
        self.platform = platform
        self.fn = fn
        self.t = t_start                    # simulated time cursor
        self.charged = 0.0

    def charge(self, seconds: float):
        """Advance simulated execution time inside the handler."""
        self.t += max(0.0, seconds)
        self.charged += max(0.0, seconds)

    def now(self) -> float:
        return self.t

    def invoke(self, fn_name: str, payload: dict) -> dict:
        """Synchronous downstream invocation (network hop included)."""
        self.t += self.platform.network_hop_s
        result, t_end = self.platform.invoke(fn_name, payload, self.t)
        self.t = t_end + self.platform.network_hop_s
        return result


class FaaSPlatform:
    def __init__(self, *, network_hop_s: float = 0.015,
                 straggler_deadline_s: Optional[float] = None,
                 straggler_slowdown: float = 1.0):
        self.functions: Dict[str, FunctionDef] = {}
        self.instances: Dict[str, List[_Instance]] = {}
        self.network_hop_s = network_hop_s
        self.stats: Dict[str, Dict[str, float]] = {}
        # fault-injection knobs for tests / straggler-mitigation demo
        self.straggler_deadline_s = straggler_deadline_s
        self.straggler_slowdown = straggler_slowdown
        self._fail_next: Dict[str, int] = {}

    # ---- deployment ------------------------------------------------------
    def deploy(self, fn: FunctionDef):
        if fn.name in self.functions:
            raise ValueError(f"function {fn.name!r} already deployed")
        self.functions[fn.name] = fn
        self.instances[fn.name] = []
        self.stats[fn.name] = {"invocations": 0, "cold_starts": 0,
                               "gb_s": 0.0, "cost_cents": 0.0, "errors": 0,
                               "speculative_retries": 0}

    def undeploy(self, name: str):
        self.functions.pop(name, None)
        self.instances.pop(name, None)

    # ---- fault injection (tests) -----------------------------------------
    def inject_failures(self, fn_name: str, count: int):
        self._fail_next[fn_name] = self._fail_next.get(fn_name, 0) + count

    # ---- invocation -------------------------------------------------------
    def _acquire_instance(self, fn: FunctionDef, t: float):
        """Returns (instance, is_cold, t_ready)."""
        pool = self.instances[fn.name]
        # reclaim expired containers
        pool[:] = [i for i in pool if t - i.last_used <= fn.retention_s]
        for inst in pool:
            if inst.busy_until <= t:
                return inst, False, t
        inst = _Instance(busy_until=t, last_used=t)
        pool.append(inst)
        return inst, True, t + fn.cold_start_s + fn.init_extra_s

    def invoke(self, fn_name: str, payload: dict, t: float,
               _speculative: bool = False) -> tuple:
        """Returns (result_dict, t_end)."""
        fn = self.functions.get(fn_name)
        if fn is None:
            raise KeyError(f"no function {fn_name!r} deployed")
        st = self.stats[fn_name]
        st["invocations"] += 1

        inst, cold, t_ready = self._acquire_instance(fn, t)
        if cold:
            st["cold_starts"] += 1

        if self._fail_next.get(fn_name, 0) > 0:
            self._fail_next[fn_name] -= 1
            st["errors"] += 1
            # platform-level retry after backoff (fault tolerance)
            emit("faas", fn_name, t, t_ready + 0.1, role=fn.role, error=True,
                 cold_start=cold)
            return self.invoke(fn_name, payload, t_ready + 0.2)

        ctx = Ctx(self, fn, t_ready)
        result = fn.handler(payload, ctx)
        duration = ctx.t - t_ready
        if duration > fn.timeout_s:
            st["errors"] += 1
            emit("faas", fn_name, t, t_ready + fn.timeout_s, role=fn.role,
                 timeout=True, cold_start=cold)
            raise FaaSTimeout(f"{fn_name} exceeded {fn.timeout_s}s "
                              f"(ran {duration:.1f}s simulated)")

        # straggler mitigation: if this invocation ran past the deadline,
        # launch a speculative duplicate and take the earlier finisher.
        if (self.straggler_deadline_s is not None and not _speculative
                and duration > self.straggler_deadline_s):
            st["speculative_retries"] += 1
            spec_result, spec_end = self.invoke(
                fn_name, payload, t + self.straggler_deadline_s, _speculative=True)
            if spec_end < ctx.t:
                result, ctx.t = spec_result, spec_end

        inst.busy_until = ctx.t
        inst.last_used = ctx.t
        exec_s = ctx.t - t_ready
        cost = PRICING.lambda_cost(fn.memory_mb, exec_s)
        st["gb_s"] += fn.memory_mb / 1024.0 * exec_s
        st["cost_cents"] += cost
        emit("faas", fn_name, t, ctx.t, role=fn.role, cold_start=cold,
             exec_s=exec_s, cost_cents=cost, memory_mb=fn.memory_mb)
        return result, ctx.t
