"""FAME memory/caching configurations (Table 1 of the paper)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MemoryConfig:
    name: str
    client_memory: bool          # naive client-side transcript accumulation
    agentic_memory: bool         # durable agent memory (DynamoDB) + injection
    mcp_caching: bool            # S3 invocation cache
    s3_files: bool               # S3 file handling (URLs instead of payloads)


E = MemoryConfig("E", client_memory=False, agentic_memory=False,
                 mcp_caching=False, s3_files=False)
N = MemoryConfig("N", client_memory=True, agentic_memory=False,
                 mcp_caching=False, s3_files=False)
C = MemoryConfig("C", client_memory=True, agentic_memory=False,
                 mcp_caching=True, s3_files=True)
M = MemoryConfig("M", client_memory=True, agentic_memory=True,
                 mcp_caching=False, s3_files=True)
MC = MemoryConfig("M+C", client_memory=True, agentic_memory=True,
                  mcp_caching=True, s3_files=True)

CONFIGS = {c.name: c for c in (E, N, C, M, MC)}
