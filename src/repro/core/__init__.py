"""FAME core — the paper's contribution as a composable library."""
from repro.core.config import CONFIGS, MemoryConfig  # noqa: F401
from repro.core.faas import FaaSPlatform, FunctionDef  # noqa: F401
from repro.core.mcp import FastMCP  # noqa: F401
from repro.core.runtime import FameRuntime  # noqa: F401
from repro.core.telemetry import Trace, use_trace  # noqa: F401
from repro.core.wrapper import fame_wrapper, wrap_server  # noqa: F401
