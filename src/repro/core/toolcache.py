"""MCP invocation cache (§3.3.2): S3-backed, content-hash keys, TTL.

Cache key = H(tool name, canonicalized arguments); entries live in an object
store bucket with the TTL in metadata. Developers set per-tool TTLs —
``-1`` (infinite; e.g. DOI downloads), ``0`` (never cache; e.g. stock quotes),
or a finite number of seconds.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Optional, Tuple

from repro.core.objectstore import ObjectStore
from repro.core.telemetry import emit

CACHE_BUCKET = "fame-mcp-cache"


def cache_key(tool: str, args: dict) -> str:
    canon = json.dumps({"tool": tool, "args": args}, sort_keys=True, default=str)
    return hashlib.sha256(canon.encode()).hexdigest()


class CacheManager:
    def __init__(self, store: ObjectStore, *, enabled: bool = True,
                 upload_latency_s: float = 0.19, lookup_latency_s: float = 0.03):
        self.store = store
        self.enabled = enabled
        self.upload_latency_s = upload_latency_s     # §5.3.1 measured 0.19s
        self.lookup_latency_s = lookup_latency_s
        self.hits = 0
        self.misses = 0

    def lookup(self, tool: str, args: dict, ttl_s: float,
               t: Optional[float] = None) -> Tuple[bool, Any]:
        if not self.enabled or ttl_s == 0:
            return False, None
        key = cache_key(tool, args)
        obj = self.store.get(CACHE_BUCKET, key, t=t)
        if obj is None:
            self.misses += 1
            emit("cache", tool, t or 0, t or 0, hit=False)
            return False, None
        self.hits += 1
        emit("cache", tool, t or 0, t or 0, hit=True)
        return True, json.loads(obj.data.decode())

    def store_latency(self) -> float:
        return self.upload_latency_s

    def put(self, tool: str, args: dict, result: Any, ttl_s: float,
            t: Optional[float] = None):
        if not self.enabled or ttl_s == 0:
            return
        key = cache_key(tool, args)
        self.store.put(CACHE_BUCKET, key, json.dumps(result, default=str).encode(),
                       {"ttl_s": None if ttl_s < 0 else ttl_s, "tool": tool}, t=t)
