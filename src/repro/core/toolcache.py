"""MCP invocation cache (§3.3.2): S3-backed, content-hash keys, TTL.

Cache key = H(tool name, canonicalized arguments); entries live in an object
store bucket with the TTL in metadata. Developers set per-tool TTLs —
``-1`` (infinite; e.g. DOI downloads), ``0`` (never cache; e.g. stock quotes),
or a finite number of seconds.

Canonicalization is explicit: only JSON-safe argument values participate in
the key (None, bool, int, finite float, str, list/tuple, dict with str keys).
Anything else raises ``TypeError`` instead of being silently keyed by its
``str()`` repr — two distinct objects with equal reprs must not collide, and
a non-JSON type sneaking into a key is a caching bug at the call site, not
something to paper over.
"""
from __future__ import annotations

import hashlib
import json
import math
from typing import Any, Optional, Tuple

from repro.core.objectstore import ObjectStore
from repro.core.telemetry import emit

CACHE_BUCKET = "fame-mcp-cache"


def canonicalize(value: Any, path: str = "args") -> Any:
    """Canonical JSON-safe form of a tool-argument value.

    Tuples become lists, dict keys are required to be strings (ordering is
    handled by sorted serialization, not here). Non-finite floats and any
    other type raise ``TypeError`` naming the offending path.
    """
    if value is None or isinstance(value, (bool, str)):
        return value
    if isinstance(value, int):
        return value
    if isinstance(value, float):
        if not math.isfinite(value):
            raise TypeError(
                f"tool argument {path} is a non-finite float ({value!r}); "
                "non-finite floats have no canonical JSON form")
        return value
    if isinstance(value, (list, tuple)):
        return [canonicalize(v, f"{path}[{i}]") for i, v in enumerate(value)]
    if isinstance(value, dict):
        for k in value:
            if not isinstance(k, str):
                raise TypeError(
                    f"tool argument {path} has a non-string dict key "
                    f"({k!r}); cache keys require string-keyed mappings")
        return {k: canonicalize(value[k], f"{path}.{k}")
                for k in sorted(value)}
    raise TypeError(
        f"tool argument {path} has non-JSON type {type(value).__name__}; "
        "pass JSON-safe values (None/bool/int/float/str/list/dict) or mark "
        "the tool ttl_s=0 / cacheable=False")


def canonical_args_text(args: dict) -> str:
    """Deterministic rendering of tool arguments — shared by the cache key
    and the serving layer's tool-stream injection (fame/toolflow.py), so a
    cached result re-enters the token stream byte-identically."""
    return json.dumps(canonicalize(args), sort_keys=True,
                      separators=(",", ":"))


def cache_key(tool: str, args: dict) -> str:
    canon = json.dumps({"tool": tool, "args": canonicalize(args)},
                       sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()


class CacheManager:
    def __init__(self, store: ObjectStore, *, enabled: bool = True,
                 upload_latency_s: float = 0.19, lookup_latency_s: float = 0.03):
        self.store = store
        self.enabled = enabled
        self.upload_latency_s = upload_latency_s     # §5.3.1 measured 0.19s
        self.lookup_latency_s = lookup_latency_s
        self.hits = 0
        self.misses = 0

    def lookup(self, tool: str, args: dict, ttl_s: float,
               t: Optional[float] = None) -> Tuple[bool, Any]:
        if not self.enabled or ttl_s == 0:
            return False, None
        key = cache_key(tool, args)
        obj = self.store.get(CACHE_BUCKET, key, t=t)
        if obj is None:
            self.misses += 1
            emit("cache", tool, t or 0, t or 0, hit=False)
            return False, None
        self.hits += 1
        emit("cache", tool, t or 0, t or 0, hit=True)
        return True, json.loads(obj.data.decode())

    def store_latency(self) -> float:
        return self.upload_latency_s

    def put(self, tool: str, args: dict, result: Any, ttl_s: float,
            t: Optional[float] = None):
        if not self.enabled or ttl_s == 0:
            return
        key = cache_key(tool, args)
        self.store.put(CACHE_BUCKET, key, json.dumps(result, default=str).encode(),
                       {"ttl_s": None if ttl_s < 0 else ttl_s, "tool": tool}, t=t)
