"""MCP deployment planning: singleton vs consolidated functions (§3.3.2/5.3.2).

* singleton  — every MCP server gets its own Lambda with its own (minimal)
  memory setting; more cold starts, cheaper per-invocation GB-ms.
* consolidated — all servers an application uses are fused into ONE Lambda
  exposing every tool; memory = max over constituents; one warm container
  serves every tool (fewer cold starts), init is heavier (bigger package).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.core.faas import FunctionDef
from repro.core.wrapper import WrappedServer


@dataclasses.dataclass
class DeploymentPlan:
    mode: str                              # "singleton" | "consolidated"
    functions: List[FunctionDef]
    tool_to_function: Dict[str, str]


def plan_singleton(wrapped: Sequence[WrappedServer], *,
                   cold_start_s: float = 1.2) -> DeploymentPlan:
    fns, mapping = [], {}
    for w in wrapped:
        fn = w.function_def(cold_start_s=cold_start_s)
        fns.append(fn)
        for tool in w.server.tools:
            mapping[tool] = fn.name
    return DeploymentPlan("singleton", fns, mapping)


def plan_consolidated(wrapped: Sequence[WrappedServer], name: str, *,
                      cold_start_s: float = 1.2,
                      init_extra_per_server_s: float = 0.25) -> DeploymentPlan:
    """Fuse all servers into one function; memory = max of constituents."""
    memory = max(w.server.memory_mb for w in wrapped)
    by_tool = {}
    for w in wrapped:
        for tool in w.server.tools:
            by_tool[tool] = w

    def handler(payload: dict, ctx) -> dict:
        request = payload["body"] if isinstance(payload.get("body"), dict) else payload
        method = request.get("method")
        if method == "tools/call":
            tool = (request.get("params") or {}).get("name", "")
            w = by_tool.get(tool)
            if w is None:
                return {"statusCode": 200, "body": {
                    "jsonrpc": "2.0", "id": request.get("id"),
                    "error": {"code": -32601, "message": f"unknown tool {tool!r}"}}}
            return w.lambda_handler(payload, ctx)
        # tools/list & initialize: merge across constituents
        if method == "tools/list":
            tools = []
            for w in wrapped:
                tools.extend(t.schema() for t in w.server.tools.values())
            return {"statusCode": 200, "body": {
                "jsonrpc": "2.0", "id": request.get("id"),
                "result": {"tools": tools}}}
        return wrapped[0].lambda_handler(payload, ctx)

    fn = FunctionDef(name=name, handler=handler, memory_mb=memory,
                     cold_start_s=cold_start_s,
                     init_extra_s=init_extra_per_server_s * (len(wrapped) - 1),
                     role="mcp")
    mapping = {tool: name for tool in by_tool}
    return DeploymentPlan("consolidated", [fn], mapping)
