"""Telemetry: spans, counters and per-run metric aggregation.

Every FaaS invocation, MCP call and LLM call emits a span onto the active
``Trace``; benchmarks aggregate them into the paper's figures (latency
breakdowns, token counts, cost decomposition, cache hits, tool-call counts).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    kind: str                 # faas | mcp | llm | workflow | cache | store
    name: str
    t_start: float
    t_end: float
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclasses.dataclass
class Trace:
    spans: List[Span] = dataclasses.field(default_factory=list)

    def add(self, kind, name, t_start, t_end, **attrs):
        s = Span(kind, name, t_start, t_end, attrs)
        self.spans.append(s)
        return s

    # ---- aggregations used by benchmarks -------------------------------
    def total(self, kind: str, attr: str) -> float:
        return sum(s.attrs.get(attr, 0) for s in self.spans if s.kind == kind)

    def count(self, kind: str, name_prefix: str = "") -> int:
        return sum(1 for s in self.spans
                   if s.kind == kind and s.name.startswith(name_prefix))

    def duration_of(self, kind: str, name_prefix: str = "") -> float:
        return sum(s.duration for s in self.spans
                   if s.kind == kind and s.name.startswith(name_prefix))

    def llm_tokens(self):
        i = self.total("llm", "input_tokens")
        o = self.total("llm", "output_tokens")
        return int(i), int(o)

    def cost_breakdown(self) -> Dict[str, float]:
        return {
            "llm_cents": self.total("llm", "cost_cents"),
            "faas_agent_cents": sum(s.attrs.get("cost_cents", 0) for s in self.spans
                                    if s.kind == "faas" and s.attrs.get("role") == "agent"),
            "faas_mcp_cents": sum(s.attrs.get("cost_cents", 0) for s in self.spans
                                  if s.kind == "faas" and s.attrs.get("role") == "mcp"),
            "workflow_cents": self.total("workflow", "cost_cents"),
            "store_cents": self.total("store", "cost_cents"),
        }


_tls = threading.local()


def current_trace() -> Optional[Trace]:
    return getattr(_tls, "trace", None)


@contextlib.contextmanager
def use_trace(trace: Trace):
    prev = getattr(_tls, "trace", None)
    _tls.trace = trace
    try:
        yield trace
    finally:
        _tls.trace = prev


def emit(kind, name, t_start, t_end, **attrs):
    tr = current_trace()
    if tr is not None:
        tr.add(kind, name, t_start, t_end, **attrs)
