"""Durable agent memory (§3.2): automated persistence + injection.

Memory entries are the accumulated agent message state of one workflow
invocation — user request, LLM interactions, tool inputs/outputs, final
response — keyed by ``session_id`` with an ``invocation_id`` field. The
Evaluator persists a NEW entry per invocation (delta only: prior entries
already exist); the Planner's context is bootstrapped by injecting all prior
entries for the session.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.core.kvstore import KVStore

MEMORY_TABLE = "fame-agent-memory"


@dataclasses.dataclass
class MemoryEntry:
    session_id: str
    invocation_id: str
    user_request: str
    messages: List[Dict[str, Any]]          # role/content (+ tool_call metadata)
    final_response: str

    def to_item(self) -> dict:
        return dataclasses.asdict(self)


class AgentMemory:
    def __init__(self, kv: KVStore, enabled: bool = True):
        self.kv = kv
        self.enabled = enabled

    @staticmethod
    def _key(session_id: str, invocation_id: str) -> str:
        return f"{session_id}#{invocation_id}"

    # --- persistence (Evaluator side) -------------------------------------
    def persist(self, entry: MemoryEntry, t: Optional[float] = None):
        if not self.enabled:
            return
        self.kv.put(MEMORY_TABLE, self._key(entry.session_id, entry.invocation_id),
                    entry.to_item(), t=t)

    # --- injection (Planner side) ------------------------------------------
    def recall(self, session_id: str, t: Optional[float] = None) -> List[MemoryEntry]:
        if not self.enabled:
            return []
        items = self.kv.query_prefix(MEMORY_TABLE, f"{session_id}#", t=t)
        return [MemoryEntry(**it) for it in items]

    def render_context(self, session_id: str, t: Optional[float] = None) -> str:
        """Serialize prior memory for injection into the Planner's context."""
        entries = self.recall(session_id, t=t)
        if not entries:
            return ""
        parts = ["[AGENT MEMORY — prior invocations in this session]"]
        for e in entries:
            parts.append(f"--- invocation {e.invocation_id} ---")
            parts.append(f"user: {e.user_request}")
            for m in e.messages:
                content = m.get("content", "")
                role = m.get("role", "?")
                if role == "tool":
                    args = json.dumps(m.get("arguments", {}), sort_keys=True)
                    parts.append(f"[ToolMessage tool={m.get('tool')} args={args}]\n{content}")
                else:
                    parts.append(f"{role}: {content}")
            parts.append(f"final: {e.final_response}")
        return "\n".join(parts)
