"""Pricing tables (AWS ap-south-1-ish + OpenAI GPT-4o-mini, as in the paper).

All monetary values in US cents (¢) to match the paper's figures.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Pricing:
    # FaaS (AWS Lambda-like)
    lambda_gb_s_cents: float = 1.6667e-3        # $0.0000166667 per GB-s
    lambda_request_cents: float = 2e-5          # $0.20 per 1M requests
    # Workflow orchestration (Step Functions standard)
    stepfn_transition_cents: float = 2.5e-3     # $25 per 1M state transitions
    # Object store (S3): per-request; storage negligible at our scale
    s3_put_cents: float = 5e-4
    s3_get_cents: float = 4e-5
    # KV store (DynamoDB on-demand)
    kv_write_cents: float = 1.25e-4
    kv_read_cents: float = 2.5e-5
    # LLM (GPT-4o-mini)
    llm_input_per_mtok_cents: float = 15.0      # $0.15 / 1M input tokens
    llm_output_per_mtok_cents: float = 60.0     # $0.60 / 1M output tokens

    def lambda_cost(self, memory_mb: int, duration_s: float) -> float:
        return (memory_mb / 1024.0) * duration_s * self.lambda_gb_s_cents \
            + self.lambda_request_cents

    def llm_cost(self, in_tokens: int, out_tokens: int) -> float:
        return (in_tokens * self.llm_input_per_mtok_cents
                + out_tokens * self.llm_output_per_mtok_cents) / 1e6


PRICING = Pricing()
