"""DynamoDB-analogue KV store: tables, composite keys, conditional puts.

Backs FAME's durable agent memory (§3.2): one table keyed by ``session_id``
with ``invocation_id``-indexed entries appended per workflow invocation.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from repro.core.pricing import PRICING
from repro.core.telemetry import emit


class KVStore:
    def __init__(self, clock=None):
        self._tables: Dict[str, Dict[str, Dict[str, Any]]] = {}
        self.clock = clock

    def _now(self):
        return self.clock.now() if self.clock is not None else time.time()

    def put(self, table: str, key: str, item: Dict[str, Any],
            t: Optional[float] = None, if_not_exists: bool = False) -> bool:
        tb = self._tables.setdefault(table, {})
        if if_not_exists and key in tb:
            return False
        now = t if t is not None else self._now()
        tb[key] = dict(item)
        emit("store", f"kv:put:{table}", now, now, cost_cents=PRICING.kv_write_cents)
        return True

    def get(self, table: str, key: str, t: Optional[float] = None) -> Optional[Dict[str, Any]]:
        now = t if t is not None else self._now()
        emit("store", f"kv:get:{table}", now, now, cost_cents=PRICING.kv_read_cents)
        item = self._tables.get(table, {}).get(key)
        return dict(item) if item is not None else None

    def query_prefix(self, table: str, prefix: str, t: Optional[float] = None) -> List[Dict[str, Any]]:
        now = t if t is not None else self._now()
        tb = self._tables.get(table, {})
        keys = sorted(k for k in tb if k.startswith(prefix))
        emit("store", f"kv:query:{table}", now, now,
             cost_cents=PRICING.kv_read_cents * max(1, len(keys)))
        return [dict(tb[k]) for k in keys]

    def update(self, table: str, key: str, updates: Dict[str, Any],
               t: Optional[float] = None):
        tb = self._tables.setdefault(table, {})
        item = tb.setdefault(key, {})
        item.update(updates)
        now = t if t is not None else self._now()
        emit("store", f"kv:update:{table}", now, now, cost_cents=PRICING.kv_write_cents)
