"""MCP data layer: FastMCP-style server + JSON-RPC 2.0 envelopes.

Mirrors Anthropic's python-sdk surface that the paper builds on: developers
declare tools with ``@mcp.tool()``; the server answers ``initialize``,
``tools/list`` and ``tools/call`` JSON-RPC requests. Transport here is the
FaaS invoke path (the paper wraps servers in Lambda Function URLs).
"""
from __future__ import annotations

import dataclasses
import inspect
import typing
from typing import Any, Callable, Dict, List, Optional

MCP_PROTOCOL_VERSION = "2025-06-18"


@dataclasses.dataclass
class ToolDef:
    name: str
    fn: Callable
    description: str
    params: Dict[str, str]                   # name -> type string
    is_async: bool = False
    # deterministic latency model: base + per-byte scan cost (simulated)
    base_latency_s: float = 0.05
    per_kb_s: float = 0.0
    cacheable: bool = True
    ttl_s: float = -1.0                      # -1 = infinite TTL; 0 = no caching

    def schema(self) -> dict:
        return {"name": self.name, "description": self.description,
                "inputSchema": {"type": "object", "properties": {
                    k: {"type": v} for k, v in self.params.items()}}}


class FastMCP:
    """Minimal FastMCP-compatible server interface."""

    def __init__(self, name: str, *, memory_mb: int = 512):
        self.name = name
        self.memory_mb = memory_mb
        self.tools: Dict[str, ToolDef] = {}

    def tool(self, *, description: str = "", base_latency_s: float = 0.05,
             per_kb_s: float = 0.0, cacheable: bool = True, ttl_s: float = -1.0):
        def deco(fn):
            hints = typing.get_type_hints(fn)
            params = {p: getattr(hints.get(p, str), "__name__", "string")
                      for p in inspect.signature(fn).parameters if p != "ctx"}
            self.tools[fn.__name__] = ToolDef(
                name=fn.__name__, fn=fn,
                description=description or (fn.__doc__ or "").strip().split("\n")[0],
                params=params, is_async=inspect.iscoroutinefunction(fn),
                base_latency_s=base_latency_s, per_kb_s=per_kb_s,
                cacheable=cacheable, ttl_s=ttl_s)
            return fn
        return deco

    # ---- JSON-RPC 2.0 data layer ----------------------------------------
    def handle_rpc(self, request: dict, runtime=None) -> dict:
        rid = request.get("id")
        method = request.get("method")
        try:
            if method == "initialize":
                result = {"protocolVersion": MCP_PROTOCOL_VERSION,
                          "serverInfo": {"name": self.name, "version": "1.0"},
                          "capabilities": {"tools": {}}}
            elif method == "tools/list":
                result = {"tools": [t.schema() for t in self.tools.values()]}
            elif method == "tools/call":
                params = request.get("params", {})
                tool = self.tools.get(params.get("name", ""))
                if tool is None:
                    raise KeyError(f"unknown tool {params.get('name')!r}")
                args = params.get("arguments", {})
                out = _run_tool(tool, args, runtime)
                result = {"content": [{"type": "text", "text": str(out)}],
                          "structuredContent": out if isinstance(out, dict) else None,
                          "isError": False}
            else:
                raise ValueError(f"unknown method {method!r}")
            return {"jsonrpc": "2.0", "id": rid, "result": result}
        except Exception as e:  # noqa: BLE001 — JSON-RPC error envelope
            return {"jsonrpc": "2.0", "id": rid,
                    "error": {"code": -32000, "message": f"{type(e).__name__}: {e}"}}


def _run_tool(tool: ToolDef, args: dict, runtime) -> Any:
    """Execute a tool, resolving async and injecting the runtime ctx."""
    kwargs = dict(args)
    if "ctx" in inspect.signature(tool.fn).parameters:
        kwargs["ctx"] = runtime
    if tool.is_async:
        import asyncio
        return asyncio.get_event_loop().run_until_complete(tool.fn(**kwargs))
    return tool.fn(**kwargs)


def rpc_call(name: str, arguments: dict, rid: int = 1) -> dict:
    return {"jsonrpc": "2.0", "id": rid, "method": "tools/call",
            "params": {"name": name, "arguments": arguments}}


def rpc_tools_list(rid: int = 1) -> dict:
    return {"jsonrpc": "2.0", "id": rid, "method": "tools/list"}
