"""LLM backends for FAME agents.

Two interchangeable backends behind one interface:

* ``ScriptedOracle`` — a deterministic planner/actor/evaluator "LLM" whose
  behaviour is a function of its VISIBLE CONTEXT (exactly the paper's
  methodology for isolating systems effects, §5.3.2): if a needed fact (paper
  title, log path) is absent from context it hallucinates (→ DNF, like config
  E); if prior tool outputs are visible in injected memory it reuses them
  (§4.2 memory prompt), else it re-calls tools. Token counts are computed
  from the ACTUAL prompt strings FAME assembles.

* ``JaxLLM`` — the real serving engine (repro.serving) hosting any assigned
  architecture (``--arch``); tokenize → prefill → decode. Used by
  examples/serve_agents.py and integration tests.

Latency model: t = base + in_tokens·prefill_rate + out_tokens·decode_rate.
``rates_for_arch`` derives the rates from the architecture's dry-run roofline
terms when results/dryrun_single_pod.json is present (serving-latency ←
roofline coupling), else falls back to GPT-4o-mini-like API constants.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.core.pricing import PRICING
from repro.core.telemetry import emit


def count_tokens(text: str) -> int:
    """Deterministic token estimate (≈4 chars/token, GPT-family heuristic)."""
    return max(1, math.ceil(len(text) / 4))


@dataclasses.dataclass
class LLMResponse:
    text: str
    input_tokens: int
    output_tokens: int
    latency_s: float
    cost_cents: float


@dataclasses.dataclass
class LatencyModel:
    base_s: float = 0.45
    per_in_tok_s: float = 9e-6          # prefill-bound
    per_out_tok_s: float = 0.018        # decode-bound (~55 tok/s)


def rates_for_arch(arch: Optional[str], results_path: str = "results/dryrun_single_pod.json"):
    """Roofline-informed serving rates for an assigned architecture."""
    if arch is None or not os.path.exists(results_path):
        return LatencyModel()
    try:
        data = json.load(open(results_path))
        cells = {(r["arch"], r["shape"]): r for r in data.get("results", [])}
        pre = cells.get((arch, "prefill_32k"))
        dec = cells.get((arch, "decode_32k"))
        if not pre or not dec:
            return LatencyModel()
        from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
        def step_time(r):
            return max(r["flops"] / PEAK_FLOPS_BF16,
                       r["bytes_accessed"] / HBM_BW,
                       r["collectives"]["total_bytes"] / ICI_BW)
        pre_tokens = 32768 * 32
        dec_tokens = 128
        return LatencyModel(base_s=0.05,
                            per_in_tok_s=step_time(pre) / pre_tokens,
                            per_out_tok_s=step_time(dec) / dec_tokens)
    except Exception:
        return LatencyModel()


class LLMBackend:
    """Base: meters tokens/latency/cost; subclasses implement _generate."""

    def __init__(self, latency: Optional[LatencyModel] = None, name: str = "llm"):
        self.latency = latency or LatencyModel()
        self.name = name

    def chat(self, system: str, context: str, ctx=None) -> LLMResponse:
        prompt = system + "\n" + context
        in_tok = count_tokens(prompt)
        text = self._generate(system, context)
        out_tok = count_tokens(text)
        lat = (self.latency.base_s + in_tok * self.latency.per_in_tok_s
               + out_tok * self.latency.per_out_tok_s)
        cost = PRICING.llm_cost(in_tok, out_tok)
        t0 = ctx.now() if ctx is not None else 0.0
        if ctx is not None:
            ctx.charge(lat)
        emit("llm", self.name, t0, t0 + lat, input_tokens=in_tok,
             output_tokens=out_tok, cost_cents=cost)
        return LLMResponse(text, in_tok, out_tok, lat, cost)

    def _generate(self, system: str, context: str) -> str:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Scripted oracle
# ---------------------------------------------------------------------------


class ScriptedOracle(LLMBackend):
    """Deterministic role-conditioned generator.

    The oracle inspects only what a real LLM would see — the system prompt and
    the assembled context string — and emits valid JSON plans / tool calls /
    evaluations. App-specific planning rules are registered by the
    applications (see repro.apps.*), keyed by trigger phrases.
    """

    def __init__(self, latency: Optional[LatencyModel] = None,
                 use_memory_prompt: bool = True, name: str = "oracle"):
        super().__init__(latency, name)
        self.rules: List[Tuple[Any, Any]] = []     # (match_fn, respond_fn)
        self.use_memory_prompt = use_memory_prompt

    def add_rule(self, match_fn, respond_fn):
        self.rules.append((match_fn, respond_fn))

    def _generate(self, system: str, context: str) -> str:
        for match_fn, respond_fn in self.rules:
            if match_fn(system, context):
                return respond_fn(system, context, self)
        return json.dumps({"error": "no rule matched", "hallucination": True})


# ---------------------------------------------------------------------------
# JaxLLM — real serving engine backend
# ---------------------------------------------------------------------------


class JaxLLM(LLMBackend):
    """FAME agents on the real serving engine's sync-free fast path.

    ``engine`` is either an ``repro.serving.server.LLMServer`` (preferred:
    each agent role gets its own server *session*, keyed by its system
    prompt, so a role's growing conversation reuses its end-of-generation
    state across turns and concurrent roles co-batch through handles) or a
    legacy ``ServingEngine`` (the deprecated blocking path, kept for A/B).
    ``temperature`` / ``top_k`` ride through to the engine's on-device
    per-slot sampler; ``serving_stats`` exposes the engine's fast-path
    counters (compiles, host syncs, decode tokens, session/turn reuse) so
    agent benchmarks can report serving efficiency alongside workflow
    metrics.
    """

    def __init__(self, engine, max_new_tokens: int = 48,
                 latency: Optional[LatencyModel] = None,
                 temperature: float = 0.0, top_k: int = 0):
        super().__init__(latency or LatencyModel(base_s=0.02), name="jaxllm")
        self.engine = engine
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.top_k = top_k
        self._sessions: Dict[str, Any] = {}     # system prompt -> Session

    def _params(self):
        from repro.serving.server import SamplingParams
        return SamplingParams(max_new_tokens=self.max_new_tokens,
                              temperature=self.temperature,
                              top_k=self.top_k)

    def _server(self):
        from repro.serving.server import LLMServer
        return self.engine if isinstance(self.engine, LLMServer) else None

    def submit(self, system: str, context: str):
        """Non-blocking submission (LLMServer only): returns a Handle so N
        concurrent agent calls can co-batch before any result is drained.
        If the role's session already has a turn in flight (two concurrent
        workflows sharing one role prompt), the call falls back to a
        sessionless submit — it still co-batches and radix-shares the
        prefix, it just skips the session-tail reuse."""
        session = self._sessions.get(system)
        if session is None or session.closed:
            session = self._server().open_session()
            self._sessions[system] = session
        if session.busy:
            return self._server().submit(system + "\n" + context,
                                         self._params())
        return session.submit(system + "\n" + context, self._params())

    def _generate(self, system: str, context: str) -> str:
        if self._server() is not None:
            return self.submit(system, context).result()
        # deprecated ServingEngine path (one test keeps it covered)
        return self.engine.generate(system + "\n" + context,
                                    max_new_tokens=self.max_new_tokens,
                                    temperature=self.temperature,
                                    top_k=self.top_k)

    def serving_stats(self) -> Dict[str, Any]:
        stats = getattr(self.engine, "stats", None)
        return stats() if callable(stats) else {}
