"""Background pump (repro.serving.pump): the always-on serving loop.

Acceptance invariant (ISSUE 8): seeded outputs through a pumping server are
bit-identical to the cooperative ``step()`` loop across dense / paged /
snapshot cache modes — the pump changes WHO drives, never WHAT runs. Plus
lifecycle (close cancels, context manager, step() ownership), thread-safe
submission from many threads, and the typed crash/stall surface.
"""
import threading

import pytest

from repro.configs.registry import ARCHS
from repro.serving.server import (EngineConfig, LLMServer, PumpConfig,
                                  PumpStalledError, SamplingParams,
                                  StepOutcome)


def _cfg(arch):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)


MODES = [("qwen2.5-3b", "dense"), ("qwen2.5-3b", "paged"),
         ("recurrentgemma-9b", "paged")]          # paged resolves: pages/snaps

PROMPTS = ["alpha prompt for slot one",
           "a rather longer second prompt that crosses a bucket",
           "third prompt"]


@pytest.mark.parametrize("arch,mode", MODES)
def test_pump_bit_identical_to_cooperative(arch, mode):
    """Same weights, same seed, same submits: the pump thread must produce
    exactly the cooperative loop's outputs (temperature > 0 so the
    per-request RNG chains are exercised, not just argmax)."""
    cfg = _cfg(arch)
    ecfg = EngineConfig(cache_mode=mode, page_size=16)
    sp = SamplingParams(max_new_tokens=8, temperature=0.7)
    coop = LLMServer(cfg, num_slots=3, capacity=128, seed=3, engine_cfg=ecfg)
    hs = [coop.submit(p, sp) for p in PROMPTS]
    coop.run_until_idle()
    ref = [h.result() for h in hs]
    params = coop.params
    coop.close()

    with LLMServer(cfg, num_slots=3, capacity=128, seed=3, params=params,
                   engine_cfg=ecfg, pump=True) as srv:
        assert srv.pumping
        hs2 = [srv.submit(p, sp) for p in PROMPTS]
        assert [h.result() for h in hs2] == ref, (arch, mode)
        st = srv.stats()
        assert st["pump_alive"] and st["pump_steps"] > 0
        assert st["pump_stall_notices"] == 0


def test_pump_owns_the_step_loop():
    """While the pump runs, driving step() from another thread is a
    programming error (two threads would race the engine) — typed refusal,
    and run_until_idle() delegates to the pump instead."""
    with LLMServer(_cfg("qwen2.5-3b"), num_slots=2, capacity=64,
                   pump=True) as srv:
        with pytest.raises(RuntimeError, match="pump owns the step loop"):
            srv.step()
        h = srv.submit("hello", SamplingParams(max_new_tokens=4))
        srv.run_until_idle()                      # blocks on the pump
        assert h.status().value == "completed"
    # after close the server is cooperative again: step() works
    assert not srv.pumping
    assert srv.step() is StepOutcome.IDLE


def test_pump_close_cancels_outstanding():
    """close() without drain= must leave nothing stranded: outstanding
    requests reach terminal CANCELLED on the pump thread before it exits."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(decode_chunk=2), pump=True)
    hs = [srv.submit(f"long job {i} " * 4,
                     SamplingParams(max_new_tokens=64)) for i in range(3)]
    srv.close()
    assert all(h.request.finished for h in hs)
    assert any(h.status().value == "cancelled" for h in hs)
    eng = srv.engine
    assert not eng._queue and all(s.request is None for s in eng.slots)


def test_pump_close_drain_finishes_work():
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=2, capacity=64, pump=True)
    hs = [srv.submit(p, SamplingParams(max_new_tokens=4)) for p in PROMPTS]
    srv.close(drain=True)
    assert all(h.status().value == "completed" for h in hs)


def test_pump_close_is_idempotent():
    """Double-close — sequential, with or without drain, on the pump or
    through the server — must be a no-op, never a raise or a hang."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=2, capacity=64, pump=True)
    pump = srv._pump
    h = srv.submit("hello", SamplingParams(max_new_tokens=4))
    srv.close(drain=True)
    assert h.status().value == "completed"
    pump.close()                         # direct second close on the pump
    pump.close(drain=True)               # drain on an already-dead pump
    srv.close()                          # server-level close is also safe
    assert not pump.thread.is_alive()


def test_pump_close_while_handle_waits():
    """A handle blocked in result() while another thread closes the server
    must unblock with its partial CANCELLED output — close() cancels on
    the pump thread and the waiter sees a clean shutdown, not a spurious
    PumpStalledError and not a deadlock."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=256,
                    engine_cfg=EngineConfig(decode_chunk=2), pump=True)
    h = srv.submit("a long job " * 4, SamplingParams(max_new_tokens=128))
    box = {}

    def waiter():
        try:
            box["text"] = h.result()
        except BaseException as e:       # pragma: no cover - the regression
            box["exc"] = e

    t = threading.Thread(target=waiter, daemon=True)
    t.start()
    while h.request.status == "queued":  # let it start decoding
        pass
    srv.close()
    t.join(10.0)
    assert not t.is_alive(), "waiter deadlocked across close()"
    assert "exc" not in box, box.get("exc")
    assert h.status().value == "cancelled"
    assert box["text"] == h.request.output_text


def test_pump_concurrent_close_races_are_safe():
    """Two threads racing close() (e.g. a fleet teardown and a with-block
    exit): both return, nothing raises, outstanding work is terminal."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(decode_chunk=2), pump=True)
    pump = srv._pump
    hs = [srv.submit(f"job {i} " * 4, SamplingParams(max_new_tokens=64))
          for i in range(2)]
    errs = []

    def closer():
        try:
            pump.close()
        except BaseException as e:       # pragma: no cover - the regression
            errs.append(e)

    ts = [threading.Thread(target=closer) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(10.0)
    assert not any(t.is_alive() for t in ts)
    assert not errs, errs
    assert all(h.request.finished for h in hs)
    srv.close()                          # idempotent server-level follow-up


def test_pump_threadsafe_submit_many_threads():
    """Submits racing from many client threads: every request completes,
    and each prompt's greedy output matches the single-threaded reference
    (the command queue serializes engine access, so no interleaving can
    corrupt another request's state)."""
    cfg = _cfg("qwen2.5-3b")
    sp = SamplingParams(max_new_tokens=6)
    coop = LLMServer(cfg, num_slots=4, capacity=128)
    prompts = [f"client {i} asks question {i % 3} " for i in range(12)]
    hs = [coop.submit(p, sp) for p in prompts]
    coop.run_until_idle()
    ref = {p: h.result() for p, h in zip(prompts, hs)}
    params = coop.params
    coop.close()

    with LLMServer(cfg, num_slots=4, capacity=128, params=params,
                   pump=True) as srv:
        out = {}
        lock = threading.Lock()

        def client(shard):
            for p in shard:
                r = srv.submit(p, sp).result()
                with lock:
                    out[p] = r

        threads = [threading.Thread(target=client, args=(prompts[i::4],))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert out == ref


def test_pump_crash_surfaces_typed():
    """An engine-level crash on the pump thread must not strand waiters:
    the pump dies, waits raise PumpStalledError (with the cause chained),
    and post-mortem stats()/state reads still work inline."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=64, pump=True)
    boom = RuntimeError("injected engine crash")

    def crash():
        raise boom
    srv._step_impl = crash
    h = srv.submit("doomed", SamplingParams(max_new_tokens=4))
    with pytest.raises(PumpStalledError):
        h.result()
    assert not srv.pumping
    st = srv.stats()                               # inline post-mortem read
    assert st["pump_alive"] is False
    srv.close()


def test_pump_stall_watchdog():
    """A wedged pump (heartbeat stops — e.g. a dispatch stuck in jit)
    surfaces as a typed stall to waiters instead of a silent hang, and the
    stall is counted in stats."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                    pump=PumpConfig(stall_timeout_s=0.2, poll_s=0.02))
    release = threading.Event()
    real = srv._step_impl

    def wedged():
        release.wait(5.0)       # hold the pump thread well past the timeout
        return real()

    srv._step_impl = wedged
    h = srv.submit("hello", SamplingParams(max_new_tokens=4))
    with pytest.raises(PumpStalledError, match="stale"):
        h.result()
    assert srv._pump.stall_notices >= 1
    release.set()               # un-wedge so shutdown is clean
    srv._step_impl = real
    pump = srv._pump
    srv.close()
    # the short stall_timeout_s also bounds close()'s join — give the
    # thread real time to leave its final engine step before teardown
    pump.thread.join(30.0)
    assert not pump.thread.is_alive()
