"""Sharding rules: every arch's param/cache PartitionSpecs must be valid and
structurally complete (validated on a degenerate 1×1 mesh — axis names are
what matter; divisibility is exercised by the 512-device dry-run)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.distributed import sharding as shd
from repro.launch.input_specs import cache_specs
from repro.launch.mesh import make_host_mesh, make_test_mesh
from repro.models import Model
from repro.models import transformer as tfm


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def _all_emitted_axes():
    """Union of every logical axis name any registry arch's params emit."""
    axes = set()
    for name in sorted(ARCHS):
        tree = Model(ARCHS[name]).param_axes()
        for leaf in jax.tree.leaves(
                tree, is_leaf=lambda x: isinstance(x, tuple) and all(
                    a is None or isinstance(a, str) for a in x)):
            axes.update(leaf)
    axes.discard(None)
    return axes


@pytest.mark.parametrize("phase", ["train", "decode", "serve"])
def test_rules_round_trip_every_emitted_axis(mesh, phase):
    """Every ParamDef logical axis any serving model emits must have an
    explicit entry in the rule set. ``_axes_to_spec`` silently replicates
    unmapped names (``rules.get(a, ())``), so a new layer introducing an
    axis the rules don't know would shard nothing and nobody would notice —
    this is the tripwire."""
    rules = shd.rules_for(mesh, phase)
    emitted = _all_emitted_axes()
    missing = sorted(a for a in emitted if a not in rules)
    assert not missing, (
        f"logical axes with no {phase!r} rule (would replicate silently): "
        f"{missing}")
    for a in emitted:                    # and every mapping must be physical
        phys = rules[a]
        for ax in ((phys,) if isinstance(phys, str) else phys):
            assert ax in ("pod", "data", "model"), (a, phys)


def test_serve_rules_never_split_a_contraction(mesh):
    """The bit-exactness invariant behind the serve layout: contraction-side
    weight axes (embed, the ``*_in`` family, MoE hidden) and the pre-down-
    projection activation gather keys must all be replicated, and no float
    reduction axis may map to a mesh axis."""
    rules = shd.rules_for(mesh, "serve")
    assert rules["phase"] == "serve" and rules["mesh"] is mesh
    for contraction_side in ("embed", "heads_in", "mlp_in", "rnn_in",
                             "moe_mlp", "moe_embed", "inner", "kv_seq",
                             "heads_act", "mlp_act", "rnn_act"):
        assert rules[contraction_side] == (), contraction_side
    # batch-like dims are the only sharded ones
    assert rules["batch"] == ("data",) and rules["cache_batch"] == ("data",)
    for batch_like in ("vocab", "heads", "kv_heads", "mlp", "experts",
                       "experts_run", "rnn"):
        assert rules[batch_like] == ("model",), batch_like
    # serve param specs stay valid (no duplicate mesh axes) for every arch
    for name in sorted(ARCHS):
        pspecs = shd.param_pspecs(Model(ARCHS[name]).param_axes(), rules)
        for ps in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
            NamedSharding(mesh, ps)


def test_serve_cache_pspecs_shard_rows_not_sequence(mesh):
    """Serve cache/pool specs: batch (slot/page/row) axis over "data", KV
    head and recurrent-channel dims over "model", never the sequence dim."""
    rules = shd.rules_for(mesh, "serve")
    for name in ("qwen2.5-3b", "recurrentgemma-9b"):
        pspecs = shd.cache_pspecs(ARCHS[name], rules)
        for ps in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
            NamedSharding(mesh, ps)
            assert "data" in ps                       # a row-sharded leaf
    qwen = shd.cache_pspecs(ARCHS["qwen2.5-3b"], rules)
    assert qwen["scan"]["sub0"]["k"] == P(None, "data", None, "model", None)
    rg = shd.cache_pspecs(ARCHS["recurrentgemma-9b"], rules)
    assert rg["scan"]["sub0"]["h"] == P(None, "data", "model")


def test_make_test_mesh_shapes():
    """make_test_mesh instantiates small explicit shapes (the production
    helper hard-codes pod slices no CPU host can build) and names axes
    rightmost-aligned; an oversized shape fails with a clear message."""
    m = make_test_mesh((1, 1))
    assert m.axis_names == ("data", "model")
    n = jax.device_count()
    if n >= 2:
        m2 = make_test_mesh((1, 2))
        assert dict(m2.shape) == {"data": 1, "model": 2}
    with pytest.raises(RuntimeError, match="device_count"):
        make_test_mesh((1024, 1024))


def test_shard_put_divisibility_fallback(mesh):
    """shard_put replicates (exactly) the dims a mesh axis cannot divide —
    device_put refuses uneven shardings, and a 2-KV-head config on a 4-way
    "model" axis must still serve, just unsharded on that dim."""
    x = jnp.arange(12.0).reshape(3, 4)
    out = shd.shard_put({"w": x}, {"w": P("data", "model")}, mesh)
    assert (out["w"] == x).all()
    spec = shd._divisible_spec((3, 4), P("data", "model"), mesh)
    assert spec == P(None, "model") or mesh.shape["data"] == 1


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_pspecs_valid(mesh, name):
    model = Model(ARCHS[name])
    rules = shd.rules_for(mesh, "train")
    pspecs = shd.param_pspecs(model.param_axes(), rules)
    specs = model.param_specs()
    flat_specs = jax.tree.leaves(specs)
    flat_ps = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_specs) == len(flat_ps)
    for sds, ps in zip(flat_specs, flat_ps):
        assert isinstance(ps, P)
        assert len(ps) <= len(sds.shape)
        NamedSharding(mesh, ps)          # raises on duplicate/invalid axes


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_cache_pspecs_match_cache_structure(mesh, name):
    cfg = ARCHS[name]
    rules = shd.rules_for(mesh, "decode")
    pspecs = shd.cache_pspecs(cfg, rules)
    cspec = tfm.cache_spec(cfg, batch=2, capacity=64)
    s1 = jax.tree.structure(jax.tree.map(lambda _: 0, cspec))
    s2 = jax.tree.structure(jax.tree.map(lambda _: 0, pspecs,
                                         is_leaf=lambda x: isinstance(x, P)))
    assert s1 == s2
    for ps in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
        NamedSharding(mesh, ps)


def test_multi_pod_rules_add_pod_axis():
    mesh = make_host_mesh()
    rules_sp = shd.rules_for(mesh, "train")
    assert rules_sp["batch"] == ("data",)

    class FakeMesh:
        axis_names = ("pod", "data", "model")
    rules_mp = shd.rules_for(FakeMesh(), "train")
    assert rules_mp["batch"] == ("pod", "data")
    assert rules_mp["embed"] == ("pod", "data")


def test_constrain_noop_outside_rules_ctx():
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "batch", None) is x


def test_dryrun_grid_covers_40_cells():
    from repro.configs.registry import all_cells
    cells = list(all_cells(include_skipped=True))
    assert len(cells) == 40
    active = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(active) == 33
    # every skip is a long_500k on a full-attention arch, with a reason
    for arch, shape, _, reason in skipped:
        assert shape.name == "long_500k"
        assert not arch.is_subquadratic
        assert reason


def test_weight_stationary_decode_rules():
    mesh = make_host_mesh()
    rules = shd.rules_for(mesh, "decode", weight_stationary=True)
    assert rules["batch"] == ()                      # activations replicated
    assert rules["cache_batch"] == ("data",)         # caches stay sharded
    assert rules["mlp"] == ("model",)                # weights stay 2D-sharded
    with pytest.raises(AssertionError):
        shd.rules_for(mesh, "train", weight_stationary=True)


def test_expert_parallel_rules():
    mesh = make_host_mesh()
    base = shd.rules_for(mesh, "train")
    ep = shd.rules_for(mesh, "train", expert_parallel=True)
    assert base["experts"] == () and base["moe_embed"] == ("data",)
    assert ep["experts"] == ("data",) and ep["moe_embed"] == ()
    assert ep["experts_run"] == ("data",) and ep["moe_tokens"] == ()
    # EP param specs stay valid (no duplicate axes) for the MoE archs
    for name in ("mixtral-8x22b", "dbrx-132b"):
        pspecs = shd.param_pspecs(Model(ARCHS[name]).param_axes(), ep)
        for ps in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
            NamedSharding(mesh, ps)


def test_seqpar_decode_attention_matches_ref(mesh):
    """shard_map flash-decode (LSE psum combine) == the naive oracle."""
    import jax.numpy as jnp
    from repro.distributed.collectives import make_seqpar_decode_attention
    from repro.kernels import ref
    fn = make_seqpar_decode_attention(mesh)
    B, W, K, G, hd = 2, 32, 2, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, K * G, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, W, K, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, W, K, hd))
    for clen in (jnp.array(W - 1, jnp.int32), jnp.array([5, 20], jnp.int32)):
        with mesh:
            got = fn(q, kc, vc, clen, q_per_kv=G)
        want = ref.decode_attention(q, kc, vc, clen, q_per_kv=G)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5
