"""Sharding rules: every arch's param/cache PartitionSpecs must be valid and
structurally complete (validated on a degenerate 1×1 mesh — axis names are
what matter; divisibility is exercised by the 512-device dry-run)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.distributed import sharding as shd
from repro.launch.input_specs import cache_specs
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.models import transformer as tfm


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_pspecs_valid(mesh, name):
    model = Model(ARCHS[name])
    rules = shd.rules_for(mesh, "train")
    pspecs = shd.param_pspecs(model.param_axes(), rules)
    specs = model.param_specs()
    flat_specs = jax.tree.leaves(specs)
    flat_ps = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_specs) == len(flat_ps)
    for sds, ps in zip(flat_specs, flat_ps):
        assert isinstance(ps, P)
        assert len(ps) <= len(sds.shape)
        NamedSharding(mesh, ps)          # raises on duplicate/invalid axes


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_cache_pspecs_match_cache_structure(mesh, name):
    cfg = ARCHS[name]
    rules = shd.rules_for(mesh, "decode")
    pspecs = shd.cache_pspecs(cfg, rules)
    cspec = tfm.cache_spec(cfg, batch=2, capacity=64)
    s1 = jax.tree.structure(jax.tree.map(lambda _: 0, cspec))
    s2 = jax.tree.structure(jax.tree.map(lambda _: 0, pspecs,
                                         is_leaf=lambda x: isinstance(x, P)))
    assert s1 == s2
    for ps in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
        NamedSharding(mesh, ps)


def test_multi_pod_rules_add_pod_axis():
    mesh = make_host_mesh()
    rules_sp = shd.rules_for(mesh, "train")
    assert rules_sp["batch"] == ("data",)

    class FakeMesh:
        axis_names = ("pod", "data", "model")
    rules_mp = shd.rules_for(FakeMesh(), "train")
    assert rules_mp["batch"] == ("pod", "data")
    assert rules_mp["embed"] == ("pod", "data")


def test_constrain_noop_outside_rules_ctx():
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "batch", None) is x


def test_dryrun_grid_covers_40_cells():
    from repro.configs.registry import all_cells
    cells = list(all_cells(include_skipped=True))
    assert len(cells) == 40
    active = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(active) == 33
    # every skip is a long_500k on a full-attention arch, with a reason
    for arch, shape, _, reason in skipped:
        assert shape.name == "long_500k"
        assert not arch.is_subquadratic
        assert reason


def test_weight_stationary_decode_rules():
    mesh = make_host_mesh()
    rules = shd.rules_for(mesh, "decode", weight_stationary=True)
    assert rules["batch"] == ()                      # activations replicated
    assert rules["cache_batch"] == ("data",)         # caches stay sharded
    assert rules["mlp"] == ("model",)                # weights stay 2D-sharded
    with pytest.raises(AssertionError):
        shd.rules_for(mesh, "train", weight_stationary=True)


def test_expert_parallel_rules():
    mesh = make_host_mesh()
    base = shd.rules_for(mesh, "train")
    ep = shd.rules_for(mesh, "train", expert_parallel=True)
    assert base["experts"] == () and base["moe_embed"] == ("data",)
    assert ep["experts"] == ("data",) and ep["moe_embed"] == ()
    assert ep["experts_run"] == ("data",) and ep["moe_tokens"] == ()
    # EP param specs stay valid (no duplicate axes) for the MoE archs
    for name in ("mixtral-8x22b", "dbrx-132b"):
        pspecs = shd.param_pspecs(Model(ARCHS[name]).param_axes(), ep)
        for ps in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
            NamedSharding(mesh, ps)


def test_seqpar_decode_attention_matches_ref(mesh):
    """shard_map flash-decode (LSE psum combine) == the naive oracle."""
    import jax.numpy as jnp
    from repro.distributed.collectives import make_seqpar_decode_attention
    from repro.kernels import ref
    fn = make_seqpar_decode_attention(mesh)
    B, W, K, G, hd = 2, 32, 2, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, K * G, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, W, K, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, W, K, hd))
    for clen in (jnp.array(W - 1, jnp.int32), jnp.array([5, 20], jnp.int32)):
        with mesh:
            got = fn(q, kc, vc, clen, q_per_kv=G)
        want = ref.decode_attention(q, kc, vc, clen, q_per_kv=G)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5
