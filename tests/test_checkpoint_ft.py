"""Checkpointing + fault-tolerant loop tests."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step_dir,
                                         restore, save)
from repro.distributed.ft import FaultTolerantLoop


def _tree(x=0.0):
    return {"w": jnp.full((4, 4), x), "opt": {"m": jnp.full((4,), x * 2)},
            "cursor": jnp.array(int(x), jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 5, _tree(1.5))
    got, step = restore(d, _tree(0.0))
    assert step == 5
    assert jnp.allclose(got["w"], 1.5)
    assert jnp.allclose(got["opt"]["m"], 3.0)


def test_latest_pointer_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        save(d, s, _tree(float(s)), keep=2)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    got, step = restore(d, _tree())
    assert step == 5


def test_async_checkpointer_snapshot_isolation(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d)
    t = _tree(1.0)
    ck.save(1, t)
    t["w"] = t["w"] * 100          # mutate after save: must not leak in
    ck.wait()
    got, _ = restore(d, _tree())
    assert jnp.allclose(got["w"], 1.0)


def test_ft_loop_recovers_from_injected_failure(tmp_path):
    d = str(tmp_path / "ckpt")
    fail_at = {30}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)           # fail once
            raise RuntimeError("injected node failure")
        return {"w": state["w"] + 1.0}

    loop = FaultTolerantLoop(d, step_fn, ckpt_every=10, max_restarts=2)
    state, report = loop.run({"w": jnp.zeros(())}, num_steps=50)
    assert report.restarts == 1
    assert float(state["w"]) == 50.0        # exactly-once semantics via replay
    assert report.final_step == 50


def test_ft_loop_resumes_across_process_restart(tmp_path):
    d = str(tmp_path / "ckpt")

    def step_fn(state, step):
        return {"w": state["w"] + 1.0}

    loop = FaultTolerantLoop(d, step_fn, ckpt_every=5)
    loop.run({"w": jnp.zeros(())}, num_steps=20)
    # "new process": fresh loop resumes from the checkpoint, runs further
    loop2 = FaultTolerantLoop(d, step_fn, ckpt_every=5)
    state, report = loop2.run({"w": jnp.zeros(())}, num_steps=30)
    assert float(state["w"]) == 30.0
    assert report.steps_run == 10           # only the remaining steps
