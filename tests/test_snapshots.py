"""Per-prefix recurrent-state snapshots: arena/trie ownership, batched
verify accept-rewind for stateful archs, and snapshot-mode (cache_mode=
"paged" on recurrent/xLSTM/ring archs) engine equivalence — greedy outputs
must be identical to dense while prefilling only radix-missed suffixes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models import Model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvpool import SnapshotArena, supports_snapshots
from repro.serving.radix import RadixTree

from tests._hypothesis_compat import given, settings, st

SNAP_ARCHS = ["recurrentgemma-9b", "xlstm-350m", "mixtral-8x22b"]


def _cfg(arch, **over):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512, **over)


# ---------------------------------------------------------------------------
# gating + arena allocator
# ---------------------------------------------------------------------------


def test_supports_snapshots_gating():
    for arch in SNAP_ARCHS:
        ok, why = supports_snapshots(_cfg(arch))
        assert ok, (arch, why)
    # full-attention KV grows with the prefix -> pages, not snapshots
    ok, why = supports_snapshots(_cfg("qwen2.5-3b"))
    assert not ok and why


def test_snapshot_arena_alloc_free_roundtrip():
    arena = SnapshotArena(3)
    a, b, c = arena.alloc(), arena.alloc(), arena.alloc()
    assert sorted([a, b, c]) == [0, 1, 2]
    assert arena.alloc() is None and arena.num_free == 0
    assert arena.peak_in_use == 3
    arena.free([b])
    assert arena.num_free == 1
    with pytest.raises(ValueError):
        arena.free([b])                        # double free
    with pytest.raises(ValueError):
        arena.free([7])                        # out of range
    with pytest.raises(ValueError):
        SnapshotArena(0)


# ---------------------------------------------------------------------------
# radix trie: snapshot payloads
# ---------------------------------------------------------------------------


def test_radix_snapshot_insert_match_nearest():
    t = RadixTree(4)
    toks = list(range(12))                     # 3 complete blocks
    # snapshots at block boundaries 1 and 3
    assert t.insert_snaps(toks, {1: 7, 3: 9}) == []
    _, node = t.match(toks)
    assert t.nearest_snapshot(node) == (9, 3)
    # a prompt diverging after 2 blocks falls back to the depth-1 snapshot
    _, node2 = t.match(toks[:8] + [99, 98, 97, 96])
    assert t.nearest_snapshot(node2) == (7, 1)
    # duplicate boundary keeps the incumbent; depth out of range rejected
    assert sorted(t.insert_snaps(toks, {1: 11, 9: 12})) == [11, 12]
    t.release(node)
    t.release(node2)
    assert set(t.cached_snaps) == {7, 9}
    t.check_invariants(snapshots=True)


def test_radix_snapshot_eviction_lru_and_pinning():
    t = RadixTree(2)
    t.insert_snaps([1, 2, 3, 4], {2: 5})
    _, node = t.match([1, 2, 3, 4])            # pins the deepest node
    assert t.evict_snaps(5) == []              # pinned path survives
    t.release(node)
    freed = t.evict_snaps(5)
    assert freed == [5] and t.evicted_snaps == 1
    assert t.num_nodes == 0                    # snap-less path nodes removed


# ---------------------------------------------------------------------------
# model level: batched verify accept-rewind == sequential decode state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", SNAP_ARCHS)
def test_verify_commit_rewinds_state_to_accept_length(arch):
    """mode="verify" on a stateful arch stages per-position states; commit
    at ANY accepted length must reproduce the cache a sequential decode of
    exactly that many tokens builds (the batched replacement for per-slot
    snapshot + replay), and a lens=0 row must keep its cache bit-exactly."""
    cfg = _cfg(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P, S, cap = 11, 5, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, P + S), 0,
                              cfg.vocab_size)
    cache = model.init_cache(1, cap)
    _, cache = model.prefill(params, model.make_batch(toks[:, :P]), cache,
                             length=jnp.int32(P))
    clens = jnp.asarray([P], jnp.int32)
    lens = jnp.asarray([S], jnp.int32)
    logits_v, staged = model.verify(params, model.make_batch(toks[:, P:],
                                                             start=P),
                                    cache, clens, lens=lens)
    refs, c = [], cache
    seq_caches = []
    for i in range(S):
        lg, c = model.decode_step(params,
                                  model.make_batch(toks[:, P + i:P + i + 1],
                                                   start=P + i),
                                  c, jnp.asarray([P + i], jnp.int32))
        refs.append(lg[:, 0])
        seq_caches.append(c)
    ref = jnp.stack(refs, axis=1)
    assert float(jnp.max(jnp.abs(logits_v - ref))) < 2e-4, arch
    for n in (1, S // 2 + 1, S):               # partial and full accepts
        committed = model.verify_commit(staged, clens,
                                        jnp.asarray([n], jnp.int32), lens)
        want = seq_caches[n - 1]
        for leaf_c, leaf_w in zip(jax.tree.leaves(committed),
                                  jax.tree.leaves(want)):
            assert float(jnp.max(jnp.abs(leaf_c - leaf_w))) < 2e-4, (arch, n)
    # a row that sat the verify out keeps its pre-verify cache bit-exactly
    _, staged0 = model.verify(params, model.make_batch(toks[:, P:], start=P),
                              cache, clens, lens=jnp.asarray([0], jnp.int32))
    kept = model.verify_commit(staged0, clens, jnp.asarray([1], jnp.int32),
                               jnp.asarray([0], jnp.int32))
    for leaf_k, leaf_o in zip(jax.tree.leaves(kept), jax.tree.leaves(cache)):
        assert float(jnp.max(jnp.abs(leaf_k - leaf_o))) == 0.0, arch


# ---------------------------------------------------------------------------
# engine: snapshot mode == dense, bit for bit (greedy), with real reuse
# ---------------------------------------------------------------------------

SYS = ("You are one of several cooperating agents sharing this exact system "
       "prompt and the same conversation history prefix. ")
TURNS = ["Plan the next step of the task.",
         "Act: call the search tool now.",
         "Evaluate the tool output please.",
         "Plan the next step of the task."]   # exact repeat of turn 0


@pytest.mark.parametrize("arch", SNAP_ARCHS)
def test_snapshot_equals_dense_greedy(arch):
    cfg = _cfg(arch)
    dense = ServingEngine(cfg, num_slots=3, capacity=128)
    snap = ServingEngine(cfg, num_slots=3, capacity=128, params=dense.params,
                         engine_cfg=EngineConfig(cache_mode="paged",
                                                 page_size=16))
    assert snap.snapshots and not snap.paged
    prompts = [SYS + t for t in TURNS]
    d = [dense.generate(p, max_new_tokens=8) for p in prompts]
    s = [snap.generate(p, max_new_tokens=8) for p in prompts]
    assert d == s, arch
    st = snap.stats()
    assert st["snapshot_hits"] >= 2            # later turns restored state
    assert st["prefix_hit_tokens"] > 0
    assert st["prefix_hit_rate"] > 0.2
    assert st["snapshot_captures"] > 0


def test_snapshot_spec_combo_equals_dense():
    """Snapshots + speculative decoding together: the radix-restored state
    feeds the batched verify path and outputs stay identical to the plain
    dense engine."""
    cfg = _cfg("recurrentgemma-9b")
    dense = ServingEngine(cfg, num_slots=2, capacity=128)
    both = ServingEngine(cfg, num_slots=2, capacity=128, params=dense.params,
                         engine_cfg=EngineConfig(cache_mode="paged",
                                                 page_size=16, spec_len=6))
    prompts = [SYS + "Tool result: ERROR 429 rate limit. " * 2] * 2
    d = [dense.generate(p, max_new_tokens=32) for p in prompts]
    b = [both.generate(p, max_new_tokens=32) for p in prompts]
    assert d == b
    assert both.stats()["snapshot_hits"] >= 1  # the repeat restored state


def test_snapshot_stride_trades_hit_depth():
    """A coarser snap_stride captures fewer snapshots and still matches
    dense outputs; hits restore at the coarser boundary."""
    cfg = _cfg("xlstm-350m")
    dense = ServingEngine(cfg, num_slots=2, capacity=128)
    coarse = ServingEngine(cfg, num_slots=2, capacity=128,
                           params=dense.params,
                           engine_cfg=EngineConfig(cache_mode="paged",
                                                   page_size=16,
                                                   snap_stride=2))
    fine = ServingEngine(cfg, num_slots=2, capacity=128, params=dense.params,
                         engine_cfg=EngineConfig(cache_mode="paged",
                                                 page_size=16))
    prompts = [SYS + t for t in TURNS[:3]]
    d = [dense.generate(p, max_new_tokens=6) for p in prompts]
    assert [coarse.generate(p, max_new_tokens=6) for p in prompts] == d
    assert [fine.generate(p, max_new_tokens=6) for p in prompts] == d
    assert (coarse.stats()["snapshot_captures"]
            < fine.stats()["snapshot_captures"])
    assert (coarse.stats()["prefix_hit_tokens"]
            <= fine.stats()["prefix_hit_tokens"])


def test_snapshot_arena_exhaustion_skips_capture_not_correctness():
    """A deliberately tiny arena forces LRU trie eviction and, once every
    row backs a pinned path, capture skips — outputs must stay identical to
    dense and the accounting exact."""
    cfg = _cfg("recurrentgemma-9b")
    dense = ServingEngine(cfg, num_slots=2, capacity=128)
    tiny = ServingEngine(cfg, num_slots=2, capacity=128, params=dense.params,
                         engine_cfg=EngineConfig(cache_mode="paged",
                                                 page_size=16,
                                                 num_snapshots=2))
    prompts = [SYS + t for t in TURNS] + ["an unrelated prompt " * 3]
    d = [dense.generate(p, max_new_tokens=6) for p in prompts]
    s = [tiny.generate(p, max_new_tokens=6) for p in prompts]
    assert d == s
    st = tiny.stats()
    assert st["snapshot_evictions"] > 0
    owned = tiny.radix.check_invariants(snapshots=True)
    assert len(owned) == tiny.snaps.num_in_use


# ---------------------------------------------------------------------------
# snapshot slots never leak (hypothesis) — the PR-3 page-leak test's twin
# ---------------------------------------------------------------------------

_LEAK_ENGINE = None


def _leak_engine():
    global _LEAK_ENGINE
    if _LEAK_ENGINE is None:
        cfg = _cfg("recurrentgemma-9b")
        # tiny arena (eviction pressure) + spec_len (partial-accept rewind
        # pressure) + decode_chunk=4 (verify interleaves with the loop)
        _LEAK_ENGINE = ServingEngine(
            cfg, num_slots=2, capacity=64,
            engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                    num_snapshots=5, spec_len=5,
                                    decode_chunk=4))
    return _LEAK_ENGINE


def _leak_check(eng):
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants(snapshots=True)
    free = set(eng.snaps._free)
    assert not (owned & free)
    # exactly-once ownership: every arena row is free or trie-owned
    assert len(owned) + len(free) == eng.snaps.num_snaps


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(2, 16)),
                min_size=4, max_size=12))
@settings(max_examples=60, deadline=None)
def test_snapshot_no_slot_leak(reqs):
    """~500 snapshot-mode requests across examples (shared prefixes, random
    budgets, LRU eviction from the deliberately tiny arena, frequent draft
    rejections rewinding restored state): after every drain each arena row
    is owned exactly once — free list or radix tree — so capture / restore /
    eviction / rejected-duplicate insert never leaks or double-frees."""
    eng = _leak_engine()
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that repeats "
             "and repeats and repeats")]
    for variant, budget in reqs:
        eng.submit(pool[variant], max_new_tokens=budget)
    eng.run_until_drained()
    _leak_check(eng)


def test_snapshot_leak_engine_exercised():
    """Companion gate (and no-hypothesis fallback): the shared leak engine
    must actually capture, restore, AND evict — a silent never-snapshotted
    run would make the leak property vacuous."""
    import random
    eng = _leak_engine()
    rng = random.Random(0)
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that repeats "
             "and repeats and repeats")]
    for _ in range(8):
        for _ in range(rng.randint(4, 12)):
            eng.submit(pool[rng.randrange(4)],
                       max_new_tokens=rng.randint(2, 16))
        eng.run_until_drained()
        _leak_check(eng)
    st = eng.stats()
    assert st["snapshot_captures"] > 0
    assert st["snapshot_hits"] > 0
    assert st["snapshot_evictions"] > 0
