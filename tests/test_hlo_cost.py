"""HLO cost parser: trip-count awareness validated against XLA itself."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_equals_unroll_flops():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    s_scan = hlo_cost.analyze(_compile(f_scan, x, w).as_text())
    s_unroll = hlo_cost.analyze(_compile(f_unroll, x, w).as_text())
    analytic = 2 * 256 ** 3 * 10
    assert s_scan.flops == pytest.approx(s_unroll.flops, rel=0.02)
    assert s_scan.flops == pytest.approx(analytic, rel=0.05)


def test_unrolled_matches_xla_cost_analysis():
    def f(x, w):
        return x @ w @ w

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x, w)
    ours = hlo_cost.analyze(c.as_text()).flops
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):     # jax < 0.5 returns one dict per device
        ca = ca[0]
    xla = ca["flops"]
    assert ours == pytest.approx(xla, rel=0.05)


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s = hlo_cost.analyze(_compile(f, x, w).as_text())
    analytic = 2 * 64 ** 3 * 12
    assert s.flops == pytest.approx(analytic, rel=0.1)


def test_collective_parse_smoke():
    # no multi-device here; just ensure the summary structure is sane
    def f(x):
        return jnp.sum(x ** 2)
    s = hlo_cost.analyze(_compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32)).as_text())
    assert s.collective_bytes == 0
    assert s.bytes_accessed > 0
