"""End-to-end behaviour tests for the whole FAME system: workflow + memory +
MCP + caching + the JAX serving engine as the LLM backend."""
import jax
import pytest

from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.llm import JaxLLM, count_tokens
from repro.core.runtime import FameRuntime
from repro.configs.registry import ARCHS
from repro.serving.engine import ServingEngine


def test_end_to_end_session_mc_vs_e():
    """The paper's headline behaviour: M+C completes a whole session that
    config E cannot, with an order of magnitude fewer tokens than N."""
    results = {}
    for cname in ("E", "N", "M+C"):
        rt = FameRuntime(config=CONFIGS[cname])
        for role, o in rs.build_oracles().items():
            rt.set_llm(role, o)
        rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
        res = rt.run_session("s", rs.queries("P1"))
        results[cname] = res
    assert results["E"].dnf and not results["M+C"].dnf
    tok_n = sum(t.llm_tokens()[0] for t in results["N"].traces)
    tok_mc = sum(t.llm_tokens()[0] for t in results["M+C"].traces)
    assert tok_mc < tok_n / 5
    # and the memory store actually holds the session's entries
    rt = FameRuntime(config=CONFIGS["M+C"])
    for role, o in rs.build_oracles().items():
        rt.set_llm(role, o)
    rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
    rt.run_session("sess-42", rs.queries("P1"))
    assert len(rt.memory.recall("sess-42")) == 3


def test_agents_on_real_jax_llm_backend():
    """Plumbing test: the agents can call the actual serving engine (reduced
    arch). Outputs are untrained gibberish, so the workflow DNFs gracefully —
    what matters is that tokenize→prefill→decode ran and tokens were metered."""
    cfg = ARCHS["qwen2.5-3b"].reduced(dtype="float32", param_dtype="float32",
                                      vocab_size=512)
    engine = ServingEngine(cfg, num_slots=2, capacity=128)
    rt = FameRuntime(config=CONFIGS["M+C"], max_iterations=1)
    backend = JaxLLM(engine, max_new_tokens=8)
    for role in ("planner", "actor", "evaluator"):
        rt.set_llm(role, backend)
    rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
    res = rt.run_session("s", rs.queries("P1")[:1])
    trace = res.traces[0]
    in_tok, out_tok = trace.llm_tokens()
    assert in_tok > 0 and out_tok > 0
    assert trace.count("llm") >= 3          # planner + actor + evaluator


def test_count_tokens_monotone():
    assert count_tokens("") == 1
    assert count_tokens("abcd" * 100) == 100
