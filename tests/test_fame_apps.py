"""Integration tests: the two reference applications under every Table-1
config must reproduce the paper's qualitative behaviour matrix."""
import pytest

from repro.apps import log_analytics as la
from repro.apps import research_summary as rs
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime


def run_app(app, config_name, inp, fusion="singleton"):
    rt = FameRuntime(config=CONFIGS[config_name], fusion_mode=fusion)
    for role, o in app.build_oracles().items():
        rt.set_llm(role, o)
    rt.deploy_mcp(app.APP.servers, app.APP.sources)
    res = rt.run_session(f"sess-{inp}", app.APP.queries(inp))
    return rt, res


@pytest.mark.parametrize("app", [rs, la], ids=["RS", "LA"])
@pytest.mark.parametrize("inp_idx", [0, 1, 2])
def test_config_E_fails_followups_only(app, inp_idx):
    _, res = run_app(app, "E", app.APP.inputs[inp_idx])
    assert res.statuses[0] == "SUCCEEDED"
    assert res.statuses[1] == "FAILED" and res.statuses[2] == "FAILED"


@pytest.mark.parametrize("app", [rs, la], ids=["RS", "LA"])
@pytest.mark.parametrize("cname", ["N", "C", "M", "M+C"])
def test_non_empty_configs_complete(app, cname):
    _, res = run_app(app, cname, app.APP.inputs[0])
    assert res.statuses == ["SUCCEEDED"] * 3, res.statuses


@pytest.mark.parametrize("app", [rs, la], ids=["RS", "LA"])
def test_token_ordering_matches_paper(app):
    """N consumes far more input tokens than C/M/M+C (Fig. 5)."""
    totals = {}
    for cname in ["N", "C", "M", "M+C"]:
        _, res = run_app(app, cname, app.APP.inputs[0])
        totals[cname] = sum(t.llm_tokens()[0] for t in res.traces)
    assert totals["N"] > 2 * totals["C"]
    assert totals["N"] > 2 * totals["M+C"]


def test_rs_token_reduction_at_least_85pct():
    """Paper: ≈85–88% fewer input tokens with memory+cache (RS app)."""
    _, res_n = run_app(rs, "N", "P1")
    _, res_mc = run_app(rs, "M+C", "P1")
    n = sum(t.llm_tokens()[0] for t in res_n.traces)
    mc = sum(t.llm_tokens()[0] for t in res_mc.traces)
    assert (n - mc) / n >= 0.80, (n, mc)


@pytest.mark.parametrize("app", [rs, la], ids=["RS", "LA"])
def test_memory_reduces_tool_calls(app):
    """Fig. 4: agent memory (M) cuts MCP tool calls vs N."""
    _, res_n = run_app(app, "N", app.APP.inputs[0])
    _, res_m = run_app(app, "M", app.APP.inputs[0])
    calls_n = sum(t.count("mcp") for t in res_n.traces)
    calls_m = sum(t.count("mcp") for t in res_m.traces)
    assert calls_m < calls_n


def test_cache_hits_across_sessions_only_with_C():
    """M+C beats M when a SECOND session repeats the same preprocessing
    (the cache is cross-session; agent memory is per-session)."""
    for cname, expect_hits in [("M", 0), ("M+C", 1)]:
        rt = FameRuntime(config=CONFIGS[cname])
        for role, o in rs.build_oracles().items():
            rt.set_llm(role, o)
        rt.deploy_mcp(rs.APP.servers, rs.APP.sources)
        rt.run_session("sess-1", rs.queries("P1")[:1])
        rt.run_session("sess-2", rs.queries("P1")[:1])    # same paper, new session
        if expect_hits:
            assert rt.cache.hits >= expect_hits, cname
        else:
            assert rt.cache.hits == 0, cname


def test_cost_decomposition_llm_dominates():
    """§5.2.3: LLM cost dominates; agent-FaaS and MCP-FaaS are small."""
    _, res = run_app(rs, "N", "P1")
    total = {"llm_cents": 0.0, "faas_agent_cents": 0.0, "faas_mcp_cents": 0.0}
    for t in res.traces:
        for k, v in t.cost_breakdown().items():
            if k in total:
                total[k] += v
    assert total["llm_cents"] > 5 * total["faas_agent_cents"]
    assert total["llm_cents"] > 5 * total["faas_mcp_cents"]


def test_consolidated_fusion_fewer_cold_starts():
    rt_s, _ = run_app(la, "M+C", "L1", fusion="singleton")
    rt_c, _ = run_app(la, "M+C", "L1", fusion="consolidated")
    cs_s = sum(s["cold_starts"] for n, s in rt_s.platform.stats.items()
               if n.startswith("mcp"))
    cs_c = sum(s["cold_starts"] for n, s in rt_c.platform.stats.items()
               if n.startswith("mcp"))
    assert cs_c < cs_s


def test_results_identical_across_fusion_modes():
    _, res_s = run_app(la, "M+C", "L1", fusion="singleton")
    _, res_c = run_app(la, "M+C", "L1", fusion="consolidated")
    assert res_s.responses == res_c.responses
