"""Serving fast path: bucketed prefill, chunked decode, on-device sampling."""
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.serving.engine import EngineConfig, ServingEngine, _auto_buckets
from repro.serving.sampler import sample_batched


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["qwen2.5-3b"].reduced(dtype="float32", param_dtype="float32",
                                       vocab_size=512)


@pytest.fixture(scope="module")
def engine(cfg):
    return ServingEngine(cfg, num_slots=3, capacity=96)


# ---------------------------------------------------------------------------
# bucketed prefill
# ---------------------------------------------------------------------------


def test_auto_buckets_cover_capacity():
    assert _auto_buckets(96) == (32, 64, 96)
    assert _auto_buckets(512) == (32, 64, 128, 256, 512)
    assert _auto_buckets(16) == (16,)


def test_mixed_lengths_share_one_compiled_bucket(engine):
    """Prompts of different lengths in one bucket -> one prefill compile."""
    before = engine.stats()["prefill_compiles"]
    # 5, 12, and 25 chars -> 6..26 tokens, all within the 32-token bucket
    for p in ("short", "medium p " * 2, "quite a bit longer yet, ok"):
        engine.generate(p, max_new_tokens=4)
    after = engine.stats()["prefill_compiles"]
    assert after - before <= 1
    assert after <= len(engine.buckets)


def test_compile_count_bounded_by_buckets(engine):
    """Many distinct prompt lengths never exceed one compile per bucket."""
    for n in (3, 9, 17, 33, 41, 57, 70):
        engine.generate("x" * n, max_new_tokens=2)
    assert engine.stats()["prefill_compiles"] <= len(engine.buckets)


def test_capacity_rounded_up_to_block_w(cfg):
    eng = ServingEngine(cfg, num_slots=2, capacity=100,
                        engine_cfg=EngineConfig(block_w=64))
    assert eng.capacity == 128
    assert eng.cfg.decode_block_w == 64
    # capacity below block_w stays as requested (kernel clamps the block)
    eng2 = ServingEngine(cfg, num_slots=2, capacity=96, params=eng.params)
    assert eng2.capacity == 96


# ---------------------------------------------------------------------------
# continuous batching: admission / eviction / equivalence
# ---------------------------------------------------------------------------


def test_admission_fifo_and_eviction_under_full_queue(engine):
    """More requests than slots: FIFO admission, slots recycled, all finish."""
    reqs = [engine.submit(f"queued request number {i}", max_new_tokens=6)
            for i in range(8)]
    engine.run_until_drained()
    assert all(r.output_tokens == 6 for r in reqs)
    admit_order = [r.admit_index for r in reqs]
    assert admit_order == sorted(admit_order), admit_order
    assert all(s.request is None for s in engine.slots)


def test_chunked_equals_single_token_greedy(cfg, engine):
    """New chunked path == old one-token-per-step path, greedy decode."""
    legacy = ServingEngine(cfg, num_slots=3, capacity=96, params=engine.params,
                           engine_cfg=EngineConfig(prefill_buckets=(),
                                                   decode_chunk=1))
    prompts = ["alpha", "a rather longer prompt for the second slot here",
               "mid-size prompt text"]
    fast_out = [engine.generate(p, max_new_tokens=8) for p in prompts]
    legacy_out = [legacy.generate(p, max_new_tokens=8) for p in prompts]
    assert fast_out == legacy_out
    # and chunk=1 through the same bucketed path also agrees
    chunk1 = ServingEngine(cfg, num_slots=3, capacity=96, params=engine.params,
                           engine_cfg=EngineConfig(decode_chunk=1))
    assert [chunk1.generate(p, max_new_tokens=8) for p in prompts] == fast_out


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "xlstm-350m"])
def test_bucketed_prefill_exact_for_stateful_archs(arch):
    """Right-padded (bucketed) prefill must be bit-identical to exact-length
    prefill for recurrent / conv / mLSTM / sLSTM / windowed-attention state —
    the valid-prefix masks in models/{rglru,xlstm,transformer}.py."""
    acfg = ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)
    fast = ServingEngine(acfg, num_slots=2, capacity=64)
    exact = ServingEngine(acfg, num_slots=2, capacity=64, params=fast.params,
                          engine_cfg=EngineConfig(prefill_buckets=(),
                                                  decode_chunk=1))
    prompts = ["tiny", "a prompt long enough to cross the conv window edge"]
    assert [fast.generate(p, max_new_tokens=6) for p in prompts] == \
           [exact.generate(p, max_new_tokens=6) for p in prompts]


def test_decode_chunk_must_be_positive(cfg):
    with pytest.raises(ValueError):
        ServingEngine(cfg, num_slots=1, capacity=64,
                      engine_cfg=EngineConfig(decode_chunk=0))


def test_per_request_temperature_honored(cfg, engine):
    """Same seed + same sampling params -> identical text; decode is no
    longer hard-wired greedy (seed engine ignored Request.temperature)."""
    e1 = ServingEngine(cfg, num_slots=2, capacity=96, params=engine.params,
                       seed=11)
    e2 = ServingEngine(cfg, num_slots=2, capacity=96, params=engine.params,
                       seed=11)
    s1 = e1.generate("sample this", max_new_tokens=8, temperature=1.3, top_k=20)
    s2 = e2.generate("sample this", max_new_tokens=8, temperature=1.3, top_k=20)
    assert s1 == s2
    r1 = e1.submit("mixed batch greedy", max_new_tokens=8)
    r2 = e1.submit("mixed batch sampled", max_new_tokens=8, temperature=1.3)
    e1.run_until_drained()
    assert r1.output_tokens == 8 and r2.output_tokens == 8
    # the greedy request must match a pure-greedy engine's output
    assert engine.generate("mixed batch greedy",
                           max_new_tokens=8) == r1.output_text


def test_host_syncs_at_most_one_per_chunk(engine):
    s0 = engine.stats()
    engine.generate("count my syncs please", max_new_tokens=12)
    s1 = engine.stats()
    assert s1["host_syncs"] - s0["host_syncs"] <= \
        s1["decode_chunks"] - s0["decode_chunks"]
    assert s1["host_syncs_per_token"] <= 1.0 / min(
        engine.engine_cfg.decode_chunk, 12) + 0.51


# ---------------------------------------------------------------------------
# chunked prefill: prompts past the largest bucket extend chunk by chunk
# ---------------------------------------------------------------------------


def test_chunked_prefill_matches_single_shot(cfg, engine):
    """Buckets smaller than the prompt no longer fall back to exact-length
    compiles: the prompt prefills in bucket-sized chunks (model.extend) and
    the outputs match the single-bucket engine bit for bit."""
    small = ServingEngine(cfg, num_slots=3, capacity=96, params=engine.params,
                          engine_cfg=EngineConfig(prefill_buckets=(32,)))
    prompts = ["tiny",
               "a prompt that is comfortably longer than one thirty-two "
               "token bucket and so must be chunked across extends"]
    outs = [small.generate(p, max_new_tokens=6) for p in prompts]
    assert outs == [engine.generate(p, max_new_tokens=6) for p in prompts]
    s = small.stats()
    assert s["extend_chunks"] >= 1
    # compile count stays bounded: one prefill bucket + extend chunk shapes
    assert s["prefill_compiles"] <= 1
    assert s["extend_compiles"] <= len(small.buckets) + 1


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "xlstm-350m",
                                  "mixtral-8x22b"])
def test_chunked_prefill_exact_for_stateful_archs(arch):
    """Extend must resume recurrent / conv / xLSTM state and ring-spliced
    windowed KV exactly — chunked == single-shot for every cache family."""
    acfg = ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)
    single = ServingEngine(acfg, num_slots=2, capacity=96)
    chunked = ServingEngine(acfg, num_slots=2, capacity=96,
                            params=single.params,
                            engine_cfg=EngineConfig(prefill_buckets=(32,)))
    prompts = ["short one",
               "a much longer prompt crossing the recurrent conv window and "
               "the local attention window and the bucket boundary at once"]
    assert [chunked.generate(p, max_new_tokens=6) for p in prompts] == \
           [single.generate(p, max_new_tokens=6) for p in prompts]
    assert chunked.stats()["extend_chunks"] >= 1


# ---------------------------------------------------------------------------
# prompt accounting satellites: truncation counter + padding waste
# ---------------------------------------------------------------------------


def test_truncation_recorded_not_silent(cfg):
    eng = ServingEngine(cfg, num_slots=1, capacity=64)
    window = eng.capacity - 8 - 1
    long_prompt = "x" * 200                    # > window tokens, must truncate
    req = eng.submit(long_prompt, max_new_tokens=8)
    eng.run_until_drained()
    assert req.prompt_tokens == window
    assert req.truncated_tokens > 0
    s = eng.stats()
    assert s["truncated_requests"] == 1
    assert s["truncated_tokens"] == req.truncated_tokens
    # short prompts don't count
    req2 = eng.submit("hi", max_new_tokens=4)
    eng.run_until_drained()
    assert req2.truncated_tokens == 0
    assert eng.stats()["truncated_requests"] == 1


def test_padding_waste_reported(cfg, engine):
    eng = ServingEngine(cfg, num_slots=1, capacity=96, params=engine.params)
    req = eng.submit("abcde", max_new_tokens=4)    # 6 tokens -> 32 bucket
    eng.run_until_drained()
    s = eng.stats()
    assert s["prompt_tokens"] == req.prompt_tokens
    assert s["prefill_pad_tokens"] == 32 - req.prompt_tokens
    assert 0.0 < s["prefill_pad_frac"] < 1.0


# ---------------------------------------------------------------------------
# admission guard (satellite): max_new_tokens vs capacity
# ---------------------------------------------------------------------------


def test_submit_rejects_unsatisfiable_budget(engine):
    with pytest.raises(ValueError):
        engine.submit("p", max_new_tokens=engine.capacity - 1)
    with pytest.raises(ValueError):
        engine.submit("p", max_new_tokens=engine.capacity + 5)
    with pytest.raises(ValueError):
        engine.submit("p", max_new_tokens=0)
    # boundary: capacity - 2 leaves a 1-token prompt window and must work
    req = engine.submit("q", max_new_tokens=engine.capacity - 2)
    engine.run_until_drained()
    assert req.prompt_tokens == 1 and req.output_tokens >= 1


# ---------------------------------------------------------------------------
# on-device batched sampler
# ---------------------------------------------------------------------------


def test_sample_batched_per_row_params():
    import jax
    logits = jnp.asarray([[0.0, 1.0, 5.0, 2.0, -1.0],
                          [5.0, 1.0, 0.0, 2.0, -1.0],
                          [0.0, 1.0, 2.0, 9.0, -1.0]])
    key = jax.random.PRNGKey(0)
    # all-greedy rows == argmax; None temperature means statically greedy
    out = sample_batched(logits, key, temperature=jnp.zeros(3))
    assert out.tolist() == [2, 0, 3]
    assert sample_batched(logits, None, temperature=None).tolist() == [2, 0, 3]
    # vocab_limit masks the tail ids
    out = sample_batched(logits, key, temperature=jnp.zeros(3), vocab_limit=3)
    assert out.tolist() == [2, 0, 2]
    # mixed greedy/stochastic rows: greedy rows stay argmax, sampled rows
    # with top_k=1 are forced to the argmax too (degenerate top-k)
    temps = jnp.asarray([0.0, 2.0, 2.0])
    ks = jnp.asarray([0, 1, 1], jnp.int32)
    out = sample_batched(logits, key, temperature=temps, top_k=ks)
    assert out.tolist() == [2, 0, 3]
    # high-temperature sampling stays inside the vocab limit
    for s in range(5):
        out = sample_batched(logits, jax.random.PRNGKey(s),
                             temperature=jnp.full((3,), 50.0), vocab_limit=4)
        assert int(out.max()) < 4


def test_sample_batched_topk_ge_vocab_is_no_filter():
    """top_k >= V must degenerate to an unfiltered sample (the k-th largest
    is then the global minimum; the V - k index is clipped, never negative),
    bit-identical to top_k=0 under the same key."""
    import jax
    key = jax.random.PRNGKey(7)
    logits = jax.random.normal(key, (4, 5))
    temps = jnp.full((4,), 1.3)
    ref = sample_batched(logits, key, temperature=temps,
                         top_k=jnp.zeros(4, jnp.int32))
    for k in (5, 6, 100):
        out = sample_batched(logits, key, temperature=temps,
                             top_k=jnp.full((4,), k, jnp.int32))
        assert out.tolist() == ref.tolist(), k
    # mixed rows: only the filtered row may differ from no-filter
    mixed = sample_batched(logits, key, temperature=temps,
                           top_k=jnp.asarray([1, 9, 0, 5], jnp.int32))
    assert mixed[1:].tolist() == ref[1:].tolist()
    assert int(mixed[0]) == int(jnp.argmax(logits[0]))      # top-1 == argmax


def test_sample_batched_topk_composes_with_vocab_limit():
    """vocab_limit masks ids to -inf BEFORE top-k: a top_k spanning the
    whole limited vocab equals vocab-limit-only sampling, and masked ids are
    never produced even when top_k counts past them."""
    import jax
    key = jax.random.PRNGKey(11)
    logits = jax.random.normal(key, (3, 8))
    temps = jnp.full((3,), 2.0)
    ref = sample_batched(logits, key, temperature=temps, vocab_limit=3,
                         top_k=jnp.zeros(3, jnp.int32))
    for k in (3, 7, 8, 50):                # k >= effective vocab -> no filter
        out = sample_batched(logits, key, temperature=temps, vocab_limit=3,
                             top_k=jnp.full((3,), k, jnp.int32))
        assert out.tolist() == ref.tolist(), k
    for s in range(6):                     # masked ids never sampled
        out = sample_batched(logits, jax.random.PRNGKey(s),
                             temperature=jnp.full((3,), 50.0), vocab_limit=3,
                             top_k=jnp.full((3,), 6, jnp.int32))
        assert int(out.max()) < 3
