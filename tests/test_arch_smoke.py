"""Per-architecture smoke tests: reduced configs, one train step + decode
consistency on CPU — exercises every block family end to end."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models import Model
from repro.models import transformer as tfm
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.train_step import TrainConfig, make_train_step

ARCH_NAMES = sorted(ARCHS)


def _reduced(name):
    return ARCHS[name].reduced(dtype="float32", param_dtype="float32")


def _inputs(cfg, key, B, S):
    if cfg.modality == "audio_frames":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_shapes_and_finite(name):
    cfg = _reduced(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    inp = _inputs(cfg, jax.random.PRNGKey(1), B, S)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = model.make_batch(inp, labels=labels)
    step = make_train_step(cfg, TrainConfig(opt=AdamWConfig(lr=1e-3)))
    opt = init_opt_state(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), metrics
    assert int(opt2.step) == 1
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(name):
    cfg = _reduced(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    inp = _inputs(cfg, jax.random.PRNGKey(1), B, S + 1)
    full, _, _ = tfm.forward_logits(params, model.make_batch(inp), cfg, mode="train")
    cache = model.init_cache(B, S + 1)
    pre, cache = model.prefill(params, model.make_batch(inp[:, :S]), cache)
    dec, _ = model.decode_step(params, model.make_batch(inp[:, S:], start=S),
                               cache, jnp.array(S, jnp.int32))
    assert float(jnp.max(jnp.abs(pre - full[:, :S]))) < 2e-3
    assert float(jnp.max(jnp.abs(dec[:, 0] - full[:, S]))) < 2e-3


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_multi_step_decode_matches_prefill(name):
    """Decoding tokens one-by-one equals prefilling them in one shot."""
    cfg = _reduced(name)
    if cfg.modality == "audio_frames":
        pytest.skip("frame-embedding decode covered via engine test")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, extra = 2, 16, 4
    inp = _inputs(cfg, jax.random.PRNGKey(1), B, S + extra)
    cap = S + extra
    cache = model.init_cache(B, cap)
    _, cache = model.prefill(params, model.make_batch(inp[:, :S]), cache)
    outs = []
    for i in range(extra):
        logits, cache = model.decode_step(
            params, model.make_batch(inp[:, S + i:S + i + 1], start=S + i),
            cache, jnp.array(S + i, jnp.int32))
        outs.append(logits[:, 0])
    cache2 = model.init_cache(B, cap)
    pre_all, _ = model.prefill(params, model.make_batch(inp), cache2)
    for i in range(extra):
        err = float(jnp.max(jnp.abs(outs[i] - pre_all[:, S + i])))
        assert err < 3e-3, (i, err)


def test_param_counts_match_published_sizes():
    expected_b = {"qwen2.5-3b": (2.5, 3.6), "chatglm3-6b": (5.5, 7.0),
                  "granite-3-2b": (2.0, 3.0), "mistral-nemo-12b": (11.0, 13.5),
                  "mixtral-8x22b": (130, 148), "dbrx-132b": (125, 140),
                  "xlstm-350m": (0.3, 0.6), "chameleon-34b": (30, 38),
                  "recurrentgemma-9b": (8.0, 11.0), "musicgen-large": (1.8, 3.0)}
    for name, (lo, hi) in expected_b.items():
        n = ARCHS[name].param_count() / 1e9
        assert lo <= n <= hi, (name, n)


def test_swa_ring_cache_decode():
    """Sliding-window arch decodes past the window with a ring cache."""
    cfg = ARCHS["mixtral-8x22b"].reduced(dtype="float32", param_dtype="float32")
    assert cfg.sliding_window == 16
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24                     # longer than the window
    inp = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    full, _, _ = tfm.forward_logits(params, model.make_batch(inp), cfg, mode="train")
    cache = model.init_cache(B, S + 1)   # window-capped internally
    _, cache = model.prefill(params, model.make_batch(inp[:, :S]), cache)
    dec, _ = model.decode_step(params, model.make_batch(inp[:, S:], start=S),
                               cache, jnp.array(S, jnp.int32))
    assert float(jnp.max(jnp.abs(dec[:, 0] - full[:, S]))) < 2e-3
