"""Replica fleet serving (repro.serving.fleet): router, stickiness,
failover, elasticity.

Acceptance invariants (ISSUE 10):

* placement — prefix-affine prompts land where the cached pages live;
  cold/disjoint traffic spreads least-loaded; a saturated replica spills
  admission to a peer BEFORE its own shed path fires;
* sessions — sticky to their replica (turn N+1 reuses the retained tail
  there), and a replica crash migrates them via journal replay with the
  next turn's greedy output bit-identical to an uninterrupted server;
* elasticity — drain() quiesces + migrates + closes without losing a
  session; add_replica() takes traffic;
* the unit-level migration precondition — a journal replayed into a FRESH
  ``LLMServer`` instance (same config, different object) continues greedy
  bit-identically in all three cache modes.
"""
import threading
import time

import pytest

from repro.configs.registry import ARCHS
from repro.serving.faults import OverloadError
from repro.serving.fleet import FleetServer
from repro.serving.server import (EngineConfig, LLMServer, OverloadPolicy,
                                  SamplingParams)

T1 = "user: hello there assistant:"
DELTA = " user: and what else? assistant:"


def _cfg(arch="qwen2.5-3b"):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)


def _ecfg(mode="paged", page_size=8):
    return EngineConfig(cache_mode=mode, page_size=page_size)


def _fleet(cfg=None, **kw):
    kw.setdefault("num_replicas", 2)
    kw.setdefault("num_slots", 2)
    kw.setdefault("capacity", 128)
    kw.setdefault("engine_cfg", _ecfg())
    kw.setdefault("seed", 3)
    kw.setdefault("digest_ttl_s", 0.0)      # always-fresh digests: routing
    return FleetServer(cfg or _cfg(), **kw)  # decisions are deterministic


def _reference_turns(cfg, params, ecfg):
    """Uninterrupted single-server two-turn session: the bit-identity
    oracle every fleet path must match (same shared weights, greedy)."""
    srv = LLMServer(cfg, num_slots=2, capacity=128, engine_cfg=ecfg, seed=3,
                    params=params)
    sp = SamplingParams(max_new_tokens=8)
    sess = srv.open_session()
    out1 = sess.submit(T1, sp).result()
    out2 = sess.submit(sess.text + DELTA, sp).result()
    srv.close()
    return out1, out2


def _replica_of(fleet, handle):
    """Which replica served this handle (handles are replica-level)."""
    for r in fleet.replicas:
        if handle._server is r.server:
            return r.idx
    raise AssertionError("handle's server is not a fleet replica")


def _slow_steps(server, delay_s=0.05):
    """Wedge a replica's engine loop so parked work lingers long enough to
    hold its slot + admission queue (the reduced model otherwise decodes
    64 tokens in well under 100ms)."""
    real = server._step_impl

    def slow():
        time.sleep(delay_s)
        return real()

    server._step_impl = slow


def _wait_saturated(replicas, timeout_s=10.0):
    """Block until every given replica shows a non-empty admission queue.
    The park/queue submits above land via pump commands, so there is a
    window where the queued request has not yet been observed; probing the
    fleet before the queues are visibly full would race the precondition."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if all(len(r.server.engine._queue) >= 1 for r in replicas):
            return
        time.sleep(0.01)
    raise AssertionError("replicas never reached admission-queue saturation")


def _crash_replica(fleet, idx, timeout_s=10.0):
    """Kill one replica's pump the way the chaos tests do: its next loop
    iteration raises, the pump dies, outstanding work fails typed."""
    srv = fleet.replicas[idx].server

    def boom():
        raise RuntimeError(f"injected crash on replica {idx}")

    srv._step_impl = boom
    deadline = time.monotonic() + timeout_s
    while srv.pumping and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not srv.pumping, "replica pump did not die"


# ---- routing ---------------------------------------------------------------
def test_fleet_roundtrip_and_gauges():
    """N sessionless submits through the fleet all complete; the fleet
    gauges account for every placement."""
    with _fleet() as fleet:
        sp = SamplingParams(max_new_tokens=6)
        hs = [fleet.submit(f"request number {i} topic {i % 3} ", sp)
              for i in range(6)]
        outs = [h.result() for h in hs]
        assert all(isinstance(o, str) for o in outs)
        st = fleet.stats()
        assert st["fleet_replicas"] == 2
        assert st["routed_requests"] == 6
        assert st["migrated_sessions"] == 0
        assert st["queued_requests"] == 0 and st["live_requests"] == 0
        # aggregate counters really sum across replicas
        assert st["decode_tokens"] == sum(
            p["decode_tokens"] for p in st["per_replica"])


def test_least_loaded_tiebreak_spreads_cold_traffic():
    """Disjoint prompts on an idle fleet: the routed-count tie-break must
    alternate replicas instead of piling everything on replica 0."""
    with _fleet() as fleet:
        sp = SamplingParams(max_new_tokens=4)
        for i in range(4):
            # drain each before the next so load scores are 0 (a pure tie);
            # prompts differ from the FIRST token so affinity never matches
            fleet.submit(f"{i} unrelated prompt {i} " * 3, sp).result()
        counts = [r.routed for r in fleet.replicas]
        assert counts == [2, 2], counts


def test_prefix_affinity_lands_on_the_warm_replica():
    """After one prompt warms a replica's radix, prompts sharing its first
    block must land on THAT replica (digest hit), and actually radix-hit
    there."""
    shared = "incident report for region seven: "      # >= page_size tokens
    with _fleet() as fleet:
        sp = SamplingParams(max_new_tokens=4)
        warm = fleet.submit(shared + "first occurrence", sp)
        warm.result()
        warm_idx = _replica_of(fleet, warm)
        fleet.run_until_idle()                         # radix adopts pages
        hs = [fleet.submit(shared + f"follow-up {i}", sp) for i in range(3)]
        for h in hs:
            h.result()
        assert all(_replica_of(fleet, h) == warm_idx for h in hs)
        st = fleet.stats()
        assert st["affinity_hits"] >= 3
        assert st["per_replica"][warm_idx]["prefix_hit_tokens"] > 0


def test_saturated_replica_spills_to_peer_before_shedding():
    """Affinity prefers the warm replica, but when its admission queue is
    at the OverloadPolicy bound and a peer has headroom, the placement
    spills — the fleet never invokes one replica's shed path while another
    could serve."""
    shared = "the hot shared prefix everybody re-sends: "
    with _fleet(num_slots=1, overload=OverloadPolicy(max_queue_depth=1),
                engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                        decode_chunk=2)) as fleet:
        sp = SamplingParams(max_new_tokens=4)
        warm = fleet.submit(shared + "warm", sp)
        warm.result()
        warm_idx = _replica_of(fleet, warm)
        fleet.run_until_idle()
        # saturate the warm replica: slow its loop, then park one long
        # decode in its slot with a full admission queue behind it
        # 96 tokens x chunk 2 x 0.25s/step ~= 12s: the parked decode outlives
        # the probe even when first-use compiles stall the pumps under suite
        # load (capacity=128 caps max_new_tokens, so stretch time per step)
        _slow_steps(fleet.replicas[warm_idx].server, delay_s=0.25)
        long_sp = SamplingParams(max_new_tokens=96)
        park = fleet.replicas[warm_idx].server.submit(
            shared + "park", long_sp)
        queued = fleet.replicas[warm_idx].server.submit(
            shared + "queued", long_sp)
        _wait_saturated([fleet.replicas[warm_idx]])
        # affinity says warm replica; saturation must spill to the peer
        h = fleet.submit(shared + "spilled arrival", sp)
        assert _replica_of(fleet, h) != warm_idx
        assert h.result() is not None
        st = fleet.stats()
        assert st["spilled_admissions"] >= 1
        park.cancel()
        queued.cancel()
        fleet.run_until_idle()


def test_all_replicas_saturated_raises_typed_overload():
    with _fleet(num_slots=1, overload=OverloadPolicy(max_queue_depth=1),
                engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                        decode_chunk=2)) as fleet:
        long_sp = SamplingParams(max_new_tokens=96)
        parked = []
        for r in fleet.replicas:            # fill every slot + every queue
            _slow_steps(r.server, delay_s=0.25)
            parked.append(r.server.submit("park " * 4, long_sp))
            parked.append(r.server.submit("queue " * 4, long_sp))
        _wait_saturated(fleet.replicas)
        with pytest.raises(OverloadError):
            fleet.submit("one too many", SamplingParams(max_new_tokens=4))
        for p in parked:
            p.cancel()
        fleet.run_until_idle()


# ---- sessions --------------------------------------------------------------
def test_sessions_sticky_and_bit_identical():
    """A fleet session's turns all go to its pinned replica, reuse the
    retained tail there (turn_prefix_hits), and reproduce the uninterrupted
    single-server outputs exactly."""
    cfg = _cfg()
    with _fleet(cfg) as fleet:
        ref1, ref2 = _reference_turns(cfg, fleet.params, _ecfg())
        sp = SamplingParams(max_new_tokens=8)
        fs = fleet.open_session()
        assert fs.replica_index is None            # pinned lazily
        assert fleet.submit(T1, sp, session=fs.sid).result() == ref1
        pin = fs.replica_index
        assert pin is not None
        assert fs.submit(fs.text + DELTA, sp).result() == ref2
        assert fs.replica_index == pin
        st = fleet.stats()["per_replica"][pin]
        assert st["turn_prefix_hits"] >= 1
        fs.close()
        assert fleet.stats()["fleet_sessions"] == 0


def test_crash_migrates_sessions_bit_identically():
    """Kill a replica's pump under live sessions: the fleet detects the
    death, journal-replays every pinned session onto the healthy peer, and
    turn 2 continues greedy-bit-identically vs an uninterrupted server."""
    cfg = _cfg()
    with _fleet(cfg) as fleet:
        ref1, ref2 = _reference_turns(cfg, fleet.params, _ecfg())
        sp = SamplingParams(max_new_tokens=8)
        sessions = [fleet.open_session() for _ in range(3)]
        for s in sessions:
            assert s.submit(T1, sp).result() == ref1
        victim = sessions[0].replica_index     # same prompt => all co-pinned
        assert all(s.replica_index == victim for s in sessions)
        _crash_replica(fleet, victim)
        # next interaction (no explicit check_health call) triggers failover
        outs = [s.submit(s.text + DELTA, sp).result() for s in sessions]
        assert outs == [ref2] * 3
        st = fleet.stats()
        assert st["replicas_failed"] == 1
        assert st["migrated_sessions"] == 3
        assert st["fleet_replicas"] == 1
        assert all(s.replica_index != victim for s in sessions)


def test_crash_with_no_sessions_keeps_serving():
    """Sessionless traffic re-routes around a dead replica; the in-flight
    request on the dead pump fails typed, later submits succeed."""
    with _fleet() as fleet:
        sp = SamplingParams(max_new_tokens=4)
        fleet.submit("before the crash", sp).result()
        _crash_replica(fleet, 0)
        h = fleet.submit("after the crash", sp)
        assert _replica_of(fleet, h) == 1
        h.result()
        assert fleet.stats()["fleet_replicas"] == 1


# ---- elasticity ------------------------------------------------------------
def test_drain_migrates_and_add_replica_takes_traffic():
    cfg = _cfg()
    with _fleet(cfg) as fleet:
        ref1, ref2 = _reference_turns(cfg, fleet.params, _ecfg())
        sp = SamplingParams(max_new_tokens=8)
        fs = fleet.open_session()
        assert fs.submit(T1, sp).result() == ref1
        pin = fs.replica_index
        fleet.drain(pin)
        st = fleet.stats()
        assert st["replicas_drained"] == 1 and st["fleet_replicas"] == 1
        assert fleet.replicas[pin].removed
        assert fs.replica_index != pin                  # migrated off
        assert fs.submit(fs.text + DELTA, sp).result() == ref2
        idx = fleet.add_replica()
        assert idx == 2 and fleet.stats()["fleet_replicas"] == 2
        # cold replica wins the routed-count tie-break for fresh traffic
        h = fleet.submit("fresh arrival for the new replica", sp)
        assert _replica_of(fleet, h) == idx
        h.result()


def test_drain_last_replica_with_sessions_refuses():
    from repro.serving.faults import PumpStalledError
    with _fleet() as fleet:
        fs = fleet.open_session()
        fs.submit(T1, SamplingParams(max_new_tokens=4)).result()
        other = 1 - fs.replica_index
        fleet.drain(other)
        with pytest.raises(PumpStalledError):
            fleet.drain(fs.replica_index)


# ---- fame drivers ----------------------------------------------------------
def test_cobatch_driver_rides_the_fleet():
    """fame/fusion.CoBatchDriver over a FleetServer: pumping=True makes it
    fan out workers; concurrent chains complete with correct outputs."""
    from repro.fame.fusion import CoBatchDriver
    cfg = _cfg()
    with _fleet(cfg) as fleet:
        ref1, _ = _reference_turns(cfg, fleet.params, _ecfg())
        sp = SamplingParams(max_new_tokens=8)
        driver = CoBatchDriver(fleet)
        sessions = [fleet.open_session() for _ in range(4)]

        def turn(s):
            return driver.call(
                lambda: fleet.submit(T1, sp, session=s.sid)).request

        thunks = [lambda s=s: turn(s) for s in sessions]
        reqs = driver.run(thunks)
        assert all(r.status == "completed" for r in reqs)
        assert all(s.text.endswith(ref1) for s in sessions)


# ---- cross-instance journal portability (unit precondition) ---------------
@pytest.mark.parametrize("arch,mode", [
    ("qwen2.5-3b", "dense"),
    ("qwen2.5-3b", "paged"),
    ("recurrentgemma-9b", "paged"),        # resolves to snapshot mode
])
def test_journal_restores_into_fresh_server_instance(arch, mode):
    """The in-memory journal OBJECT of server A, replayed into a brand-new
    LLMServer B (same config, different instance — the exact fleet
    migration path), must continue the conversation greedy-bit-identically
    in every cache mode."""
    cfg = _cfg(arch)
    ecfg = EngineConfig(cache_mode=mode, page_size=8)
    sp = SamplingParams(max_new_tokens=8)
    a = LLMServer(cfg, num_slots=2, capacity=128, engine_cfg=ecfg, seed=3)
    sess = a.open_session()
    sess.submit(T1, sp).result()

    b = LLMServer(cfg, num_slots=2, capacity=128, engine_cfg=ecfg, seed=3,
                  params=a.params)
    restored = b.restore_sessions(a.journal)     # object, not a spill path
    bs = restored[sess.sid]
    assert bs.text == sess.text and bs.turns == sess.turns

    t2 = sess.text + DELTA
    ref2 = sess.submit(t2, sp).result()          # A continues uninterrupted
    assert bs.submit(t2, sp).result() == ref2, (arch, mode)
    a.close()
    b.close()
