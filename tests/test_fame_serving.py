"""The FAME workflow runtime on the real serving stack (src/repro/fame/).

One tiny warm server for the whole module; cells assert the PR's serving
invariants directly: backend-identical workflow statuses (oracle-guided
decisions), session tail reuse on memory configs (delta billing, no history
re-prefill), the cache × radix composition (a tool-cache hit re-injects
token-identically and radix-hits), fault taxonomy → per-state Retry mapping,
and CoBatchDriver actually co-batching concurrent submits."""
import threading

import pytest

from repro.apps import log_analytics as la
from repro.configs.registry import ARCHS
from repro.core.config import CONFIGS
from repro.core.runtime import FameRuntime
from repro.core.workflow import Retry
from repro.fame import CoBatchDriver, ServingMeter, WorkflowServingRuntime
from repro.serving.faults import FaultInjector, RequestFault
from repro.serving.scheduler import EngineConfig, SamplingParams
from repro.serving.server import LLMServer

PAGE = 16
APP = la
INPUT = APP.APP.inputs[0]


@pytest.fixture(scope="module")
def server():
    cfg = ARCHS["qwen2.5-3b"].reduced(dtype="float32", param_dtype="float32",
                                      vocab_size=512)
    injector = FaultInjector(seed=0)
    srv = LLMServer(cfg, num_slots=4, capacity=2048,
                    engine_cfg=EngineConfig(cache_mode="paged",
                                            page_size=PAGE, decode_chunk=8),
                    injector=injector, seed=0)
    h = srv.submit("warmup " * 8, SamplingParams(max_new_tokens=8))
    srv.run_until_idle()
    assert h.request.finished
    return srv


def build_rt(server, config, **kw):
    meter = ServingMeter(server)
    rt = WorkflowServingRuntime(config=CONFIGS[config], server=server,
                                meter=meter,
                                params=SamplingParams(max_new_tokens=8), **kw)
    for role, oracle in APP.build_oracles().items():
        rt.set_llm(role, oracle)
    rt.deploy_mcp(APP.APP.servers, APP.APP.sources)
    return rt, meter


@pytest.fixture(scope="module")
def mc_cell(server):
    """One full M+C client session (persistent chain + caching) on the real
    server, shared by the assertions below."""
    rt, meter = build_rt(server, "M+C")
    res = rt.run_session(f"fame-test-{INPUT}", APP.APP.queries(INPUT))
    return res, meter


def test_statuses_identical_to_oracle_backend(mc_cell):
    res, _ = mc_cell
    oracle_rt = FameRuntime(config=CONFIGS["M+C"])
    for role, oracle in APP.build_oracles().items():
        oracle_rt.set_llm(role, oracle)
    oracle_rt.deploy_mcp(APP.APP.servers, APP.APP.sources)
    oracle_res = oracle_rt.run_session(f"fame-test-{INPUT}",
                                       APP.APP.queries(INPUT))
    assert res.statuses == oracle_res.statuses


def test_memory_config_reuses_session_tail(mc_cell):
    res, meter = mc_cell
    conts = meter.continuation_turns()
    assert conts, "persistent chain recorded no continuation turns"
    # continuation turns bill the delta, not the conversation
    assert meter.tail_reuse_ok()
    for r in conts:
        assert 0 < r.billed_tokens < r.prompt_tokens
    # engine-side confirmation: admitted off the retained tail
    assert all(r.prefix_hit_tokens > 0 for r in conts)
    assert meter.all_terminal()


def test_cache_hit_injection_radix_hits(server):
    # config C: sessionless but caching — repeated tool calls within the
    # session hit the MCP cache, and their re-injections must be served
    # from shared radix pages, billing zero
    rt, meter = build_rt(server, "C")
    rt.run_session(f"fame-test-c-{INPUT}", APP.APP.queries(INPUT))
    injects = meter.turns("inject")
    hits = [r for r in injects if r.cache_hit]
    misses = [r for r in injects if not r.cache_hit]
    assert hits, "no cache-hit injections in config C"
    assert misses, "no cache-miss injections in config C"
    assert meter.injection_radix_ok(PAGE)
    assert all(r.billed_tokens == 0 for r in hits)
    assert all(r.billed_tokens == r.prompt_tokens for r in misses)
    assert rt.cache.hits == len(hits)


def test_injected_fault_absorbed_by_state_retry(server):
    # a RequestFault raised by the engine surfaces through the turn into the
    # Step-Functions Retry, which re-runs the state; workflow still succeeds
    server.engine.injector.fail_next("decode", n=1, exc=RequestFault,
                                     msg="injected chaos")
    rt, meter = build_rt(server, "M+C",
                         state_retry=Retry(max_attempts=2, backoff_s=0.1))
    res = rt.run_session("fame-test-fault", APP.APP.queries(INPUT)[:1])
    assert res.statuses == ["SUCCEEDED"]
    assert "RequestFault" in {r.error_type for r in meter.records}
    assert any(r.status == "failed" for r in meter.records)
    assert meter.all_terminal()


def test_deadline_dead_letters_workflow(server):
    rt, meter = build_rt(server, "M+C",
                         state_retry=Retry(max_attempts=2, backoff_s=0.01),
                         state_deadline_s=1e-4)
    res = rt.run_session("fame-test-deadline", APP.APP.queries(INPUT)[:1])
    assert all(s == "FAILED" for s in res.statuses)
    assert {r.error_type for r in meter.records
            if r.error_type} == {"DeadlineExceeded"}
    assert meter.all_terminal()
    stats = server.stats()
    assert stats["queued_requests"] == 0 and stats["live_requests"] == 0


def test_cobatch_driver_shares_engine_steps(server):
    driver = CoBatchDriver(server)
    params = SamplingParams(max_new_tokens=8)
    before = server.stats()

    def turn(i):
        return driver.call(
            lambda: server.submit(f"cobatch worker {i} asks a question " * 3,
                                  params))

    handles = driver.run([lambda i=i: turn(i) for i in range(3)])
    assert all(h.request.finished for h in handles)
    assert len({h.request.output_text for h in handles}) >= 1
    after = server.stats()
    steps = after["engine_steps"] - before["engine_steps"]
    slot_sum = (after["active_slots_per_step"] * after["engine_steps"]
                - before["active_slots_per_step"] * before["engine_steps"])
    assert steps > 0
    assert slot_sum / steps > 1.05, "concurrent submits did not co-batch"
    assert threading.active_count() >= 1   # workers joined, none leaked


def test_sessionless_config_bills_full_prompt(server):
    # config N re-sends client history each call: every turn is sessionless
    # and bills its full rendered prompt (the Fig. 5 token bloat)
    rt, meter = build_rt(server, "N")
    rt.run_session(f"fame-test-n-{INPUT}", APP.APP.queries(INPUT)[:2])
    turns = meter.turns()
    assert turns and not meter.continuation_turns()
    assert all(r.billed_tokens == r.prompt_tokens for r in turns)
    assert all(r.session_turn == 0 for r in turns)
