"""Chaos / fault-injection suite for the serving fault layer
(serving/faults.py): transient-fault retry, per-request failure isolation,
dead-lettering, admission backoff under pool exhaustion, corruption
handling, the stall watchdog, crash-safe session recovery — and the two
load-bearing invariants under random fault schedules:

* **exactly-once ownership**: after every drain, each KV page / state
  snapshot is owned by exactly one of free list, radix tree, or a session
  tail — no leak, no double-free, whatever faults fired mid-flight.
* **fault-free isolation**: a request none of whose own dispatches faulted
  completes bit-identically to a fault-free server, even when co-batched
  requests failed, retried, or backed off around it.

Faults are injected *before* device dispatch (see faults.py), so a retried
call re-runs bit-identically and the surviving slots' device state is
untouched by a faulted call.
"""
import pytest

from repro.configs.registry import ARCHS
from repro.serving.scheduler import SamplingParams
from repro.serving.server import (CorruptionError, DeadLetterError,
                                  EngineConfig, FaultInjector, LLMServer,
                                  RequestFault, RequestStatus, RetryPolicy,
                                  SessionJournal)

from tests._hypothesis_compat import given, settings, st


def _cfg(arch):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)


@pytest.fixture(scope="module")
def qwen():
    return _cfg("qwen2.5-3b")


@pytest.fixture(scope="module")
def qwen_params(qwen):
    from repro.models import Model
    import jax
    return Model(qwen).init(jax.random.PRNGKey(0))


def _page_leak_check(srv):
    """Exactly-once page ownership: free list | radix tree | session tail."""
    eng = srv.engine
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = set(eng.kvpool._free)
    tails = {s.tail_page for s in eng._sessions.values() if s.tail_page >= 0}
    assert not (owned & free) and not (owned & tails) and not (free & tails)
    assert (len(owned) + len(free) + len(tails)
            == eng.kvpool.num_pages - eng.kvpool.reserved)


def _snap_leak_check(srv):
    """Exactly-once snapshot ownership: free list | radix | session tail."""
    eng = srv.engine
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants(snapshots=True)
    free = set(eng.snaps._free)
    tails = {s.tail_snap for s in eng._sessions.values() if s.tail_snap >= 0}
    assert not (owned & free) and not (owned & tails) and not (free & tails)
    assert len(owned) + len(free) + len(tails) == eng.snaps.num_snaps


# ---------------------------------------------------------------------------
# transient faults: bounded retry, bit-identical recovery
# ---------------------------------------------------------------------------


def test_transient_fault_retried_bit_identical(qwen, qwen_params):
    """Injected transient faults at prefill and decode are retried away;
    the output is bit-identical to a fault-free run and the handle
    COMPLETED."""
    ref = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params)
    want = ref.submit("the quick brown fox",
                      SamplingParams(max_new_tokens=10)).result()
    inj = FaultInjector(seed=0)
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params,
                    injector=inj,
                    retry=RetryPolicy(max_attempts=3, backoff_s=1e-3))
    inj.fail_next("prefill", 1)
    inj.fail_next("decode", 2)
    h = srv.submit("the quick brown fox", SamplingParams(max_new_tokens=10))
    assert h.result() == want
    assert h.status() == RequestStatus.COMPLETED
    st = srv.stats()
    assert st["dispatch_retries"] >= 3
    assert st["dead_lettered"] == 0
    assert inj.injected["prefill"] == 1 and inj.injected["decode"] == 2


def test_request_fault_isolated_to_one_handle(qwen, qwen_params):
    """A RequestFault at admission fails ONLY the poisoned handle; a
    co-batched fault-free request completes bit-identically to a fault-free
    server, and no page leaks."""
    ecfg = EngineConfig(cache_mode="paged", page_size=16)
    ref = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params,
                    engine_cfg=ecfg)
    want = ref.submit("survivor prompt",
                      SamplingParams(max_new_tokens=10)).result()
    inj = FaultInjector(seed=0)
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params,
                    engine_cfg=ecfg, injector=inj)
    inj.fail_next("extend_paged", 1, exc=RequestFault, msg="poisoned request")
    bad = srv.submit("poisoned prompt", SamplingParams(max_new_tokens=10))
    good = srv.submit("survivor prompt", SamplingParams(max_new_tokens=10))
    assert good.result() == want
    assert good.status() == RequestStatus.COMPLETED
    assert bad.status() == RequestStatus.FAILED
    assert isinstance(bad.exception(), RequestFault)
    with pytest.raises(RequestFault):
        bad.result()
    _page_leak_check(srv)


def test_decode_deadletter_terminal_and_pump_survives(qwen, qwen_params):
    """Retries exhausted on a decode chunk dead-letter the slots in that
    chunk (terminal FAILED, exception recorded) — and the engine pump keeps
    serving new requests afterwards."""
    inj = FaultInjector(seed=0)
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16),
                    injector=inj,
                    retry=RetryPolicy(max_attempts=2, backoff_s=1e-3))
    a = srv.submit("request a text", SamplingParams(max_new_tokens=12))
    b = srv.submit("request b text", SamplingParams(max_new_tokens=12))
    inj.fail_next("decode", 2)              # both attempts of one chunk
    srv.run_until_idle()
    for h in (a, b):
        assert h.status() == RequestStatus.FAILED and h.status().terminal
        assert isinstance(h.exception(), DeadLetterError)
    assert srv.stats()["dead_lettered"] == 2
    c = srv.submit("still serving", SamplingParams(max_new_tokens=4))
    assert c.result() is not None and c.status() == RequestStatus.COMPLETED
    _page_leak_check(srv)


# ---------------------------------------------------------------------------
# admission under pool exhaustion: backoff, starvation guard, never-fit
# ---------------------------------------------------------------------------


def test_pool_exhaustion_backoff_and_starvation_guard(qwen, qwen_params):
    """Injected pool exhaustion makes the head-of-line request back off
    instead of blocking the round: a later small request admits first
    (starvation guard), the denied ones retry with backoff, and everyone
    completes."""
    inj = FaultInjector(seed=0)
    srv = LLMServer(qwen, num_slots=2, capacity=128, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16),
                    injector=inj,
                    retry=RetryPolicy(max_attempts=8, backoff_s=5e-3))
    inj.exhaust_next("pool.alloc", 3)
    big = srv.submit("big request " * 8, SamplingParams(max_new_tokens=32))
    smalls = [srv.submit(f"small {i}", SamplingParams(max_new_tokens=4))
              for i in range(3)]
    srv.run_until_idle()
    for h in [big] + smalls:
        assert h.status() == RequestStatus.COMPLETED
    st = srv.stats()
    assert st["admission_retries"] >= 1
    assert st["dead_lettered"] == 0
    # FIFO says big admits first; the denials made a small overtake it
    assert min(h.request.admit_index for h in smalls) < big.request.admit_index
    _page_leak_check(srv)


def test_never_fit_dead_letters_without_crashing(qwen, qwen_params):
    """A request that can never fit the pool (even fully drained) is
    dead-lettered with a clear error instead of crashing or spinning the
    pump; the engine keeps serving."""
    srv = LLMServer(qwen, num_slots=1, capacity=64, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16,
                                            num_pages=3))
    h = srv.submit("a prompt that needs more pages than the pool holds",
                   SamplingParams(max_new_tokens=8))
    with pytest.raises(RequestFault):
        h.result()
    assert h.status() == RequestStatus.FAILED
    assert "pool too small" in str(h.exception())
    h2 = srv.submit("ok", SamplingParams(max_new_tokens=2))
    assert h2.result() is not None
    assert h2.status() == RequestStatus.COMPLETED
    _page_leak_check(srv)


# ---------------------------------------------------------------------------
# corruption: fails cleanly, ownership intact
# ---------------------------------------------------------------------------


def test_corruption_fails_cleanly_paged(qwen, qwen_params):
    inj = FaultInjector(seed=0)
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16),
                    injector=inj)
    inj.fail_next("extend_paged", 1, exc=CorruptionError,
                  msg="corrupt page id")
    bad = srv.submit("to be corrupted", SamplingParams(max_new_tokens=8))
    with pytest.raises(CorruptionError):
        bad.result()
    assert bad.status() == RequestStatus.FAILED
    good = srv.submit("to be corrupted", SamplingParams(max_new_tokens=8))
    assert good.result() is not None            # same prompt now serves fine
    _page_leak_check(srv)


def test_corruption_snapshot_restore_keeps_session_tail():
    """A corrupt snapshot restore fails only that turn; the session's
    retained tail survives, so the retried turn still reuses it."""
    cfg = _cfg("recurrentgemma-9b")
    inj = FaultInjector(seed=0)
    srv = LLMServer(cfg, num_slots=2, capacity=128,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16),
                    injector=inj)
    sess = srv.open_session()
    sess.submit("sys: agent. turn one:",
                SamplingParams(max_new_tokens=8)).result()
    tail = srv.engine._sessions[sess.sid].tail_snap
    assert tail >= 0
    inj.fail_next("snap_restore", 1, exc=CorruptionError, msg="corrupt snap")
    bad = sess.submit(sess.text + " turn two:",
                      SamplingParams(max_new_tokens=8))
    with pytest.raises(CorruptionError):
        bad.result()
    assert bad.status() == RequestStatus.FAILED
    assert srv.engine._sessions[sess.sid].tail_snap == tail   # tail intact
    retry = sess.submit(sess.text + " turn two:",
                        SamplingParams(max_new_tokens=8))
    assert retry.result() is not None
    assert retry.request.prefix_hit_tokens > 0                # tail reused
    sess.close()
    _snap_leak_check(srv)


# ---------------------------------------------------------------------------
# watchdog: stalled dispatches are detected, not fatal
# ---------------------------------------------------------------------------


def test_watchdog_flags_stalled_dispatch(qwen, qwen_params):
    inj = FaultInjector(seed=0)
    srv = LLMServer(qwen, num_slots=1, capacity=96, params=qwen_params,
                    injector=inj, watchdog_s=0.01)
    inj.stall_next("decode", 1, stall_s=0.05)
    h = srv.submit("stalled but alive", SamplingParams(max_new_tokens=8))
    assert h.result() is not None
    assert h.status() == RequestStatus.COMPLETED
    assert srv.stats()["watchdog_stalls"] >= 1
    assert inj.injected["decode.stall"] == 1


# ---------------------------------------------------------------------------
# crash-safe session recovery: journal replay is bit-identical
# ---------------------------------------------------------------------------

_T1 = "user: hello there assistant:"
_T2 = " user: and what else? assistant:"


@pytest.mark.parametrize("arch,mode", [("qwen2.5-3b", "dense"),
                                       ("qwen2.5-3b", "paged"),
                                       ("recurrentgemma-9b", "paged")])
def test_restore_sessions_bit_identical(arch, mode, tmp_path):
    """Kill a server after turn 1, restore its spilled journal on a fresh
    server: turn 2's greedy output is bit-identical to an uninterrupted
    two-turn server, in dense, paged, and snapshot modes."""
    cfg = _cfg(arch)
    ecfg = EngineConfig(cache_mode=mode, page_size=16)
    ref = LLMServer(cfg, num_slots=2, capacity=192, engine_cfg=ecfg)
    s = ref.open_session()
    t1 = s.submit(_T1, SamplingParams(max_new_tokens=12)).result()
    t2 = s.submit(s.text + _T2, SamplingParams(max_new_tokens=12)).result()

    path = str(tmp_path / "sessions.json")
    crashed = LLMServer(cfg, num_slots=2, capacity=192, engine_cfg=ecfg,
                        params=ref.params, journal_path=path)
    sa = crashed.open_session()
    assert sa.submit(_T1, SamplingParams(max_new_tokens=12)).result() == t1
    old_sid = sa.sid
    del crashed                                   # the "crash"

    fresh = LLMServer(cfg, num_slots=2, capacity=192, engine_cfg=ecfg,
                      params=ref.params)
    restored = fresh.restore_sessions(path)       # load + replay
    sb = restored[old_sid]
    assert sb.text == _T1 + t1                    # conversation text survives
    assert sb.submit(sb.text + _T2,
                     SamplingParams(max_new_tokens=12)).result() == t2
    if mode == "paged" and arch == "qwen2.5-3b":
        # the replay rebuilt the tail: turn 2 was served off retained state
        assert fresh.stats()["turn_prefix_hits"] >= 1
        _page_leak_check(fresh)


def test_session_journal_roundtrip(tmp_path):
    """Journal unit semantics: latest-state-per-sid, drop, atomic dump /
    load roundtrip."""
    j = SessionJournal()
    j.record(1, "one", [5, 6, 7], 1)
    j.record(2, "two", [8, 9], 1)
    j.record(1, "one more", [5, 6, 7, 10, 11], 2)   # overwrite, not append
    assert len(j) == 2
    assert j.get(1).all_tokens == [5, 6, 7, 10, 11] and j.get(1).turns == 2
    path = str(tmp_path / "j.json")
    j.dump(path)
    j2 = SessionJournal.load(path)
    assert [e.sid for e in j2.entries()] == [1, 2]
    assert j2.get(1).text == "one more" and j2.get(2).all_tokens == [8, 9]
    j2.drop(1)
    assert len(j2) == 1 and j2.get(1) is None
    # spill-on-record: a path-bound journal persists every update
    j3 = SessionJournal(path=str(tmp_path / "spill.json"))
    j3.record(7, "x", [1, 2], 1)
    assert SessionJournal.load(j3.path).get(7).all_tokens == [1, 2]


# ---------------------------------------------------------------------------
# hypothesis chaos: random ops under seeded fault rates
# ---------------------------------------------------------------------------

_CHAOS = None
_REF = None
_REF_CACHE = {}

_CHAOS_PROMPTS = ["sys: agent loop. task alpha",
                  "sys: agent loop. task beta",
                  "sys: agent loop. a rather longer task gamma request",
                  "unrelated short prompt"]


def _chaos_server():
    """Shared paged chaos server: tiny pool (eviction + exhaustion
    pressure), spec on (verify site live), small chunks (many fault
    windows), aggressive-but-bounded retry."""
    global _CHAOS
    if _CHAOS is None:
        inj = FaultInjector(seed=0)
        srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=2, capacity=64,
                        engine_cfg=EngineConfig(cache_mode="paged",
                                                page_size=8, num_pages=18,
                                                spec_len=4, decode_chunk=4),
                        injector=inj,
                        retry=RetryPolicy(max_attempts=3, backoff_s=1e-3))
        _CHAOS = (srv, inj)
    return _CHAOS


def _ref_output(prompt, budget):
    """Fault-free greedy reference for (prompt, budget), same params/knobs
    as the chaos server (spec off: bit-identity is the non-spec contract)."""
    global _REF
    if _REF is None:
        srv, _ = _chaos_server()
        _REF = LLMServer(_cfg("qwen2.5-3b"), num_slots=2, capacity=64,
                         params=srv.params,
                         engine_cfg=EngineConfig(cache_mode="paged",
                                                 page_size=8, num_pages=18,
                                                 decode_chunk=4))
    key = (prompt, budget)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = _REF.submit(
            prompt, SamplingParams(max_new_tokens=budget)).result()
    return _REF_CACHE[key]


@given(st.integers(0, 2 ** 16 - 1),
       st.lists(st.tuples(st.integers(0, 3), st.integers(2, 10)),
                min_size=2, max_size=6))
@settings(max_examples=15, deadline=None, derandomize=True)
def test_chaos_paged_terminal_and_exactly_once(seed, ops):
    """Random submissions under seeded fault rates on every paged site
    (prefill-extend, decode, verify, pool alloc): after the drain every
    handle is terminal, fault-free completions are bit-identical to the
    no-fault reference, and page ownership is exactly-once."""
    srv, inj = _chaos_server()
    inj._rng.seed(seed)
    inj.rates.update({"extend_paged": 0.08, "decode": 0.08, "verify": 0.05,
                      "pool.alloc": 0.05})
    try:
        handles = []
        for variant, budget in ops:
            handles.append(srv.submit(_CHAOS_PROMPTS[variant],
                                      SamplingParams(max_new_tokens=budget)))
            srv.step()
        srv.run_until_idle()
    finally:
        inj.rates.clear()
        srv.run_until_idle()
    for h in handles:
        assert h.status().terminal, h.status()
        assert h.status() in (RequestStatus.COMPLETED, RequestStatus.FAILED)
        if h.status() == RequestStatus.COMPLETED:
            # fault-free (or transparently retried) co-batched request:
            # bit-identical to the fault-free reference
            assert h.text == _ref_output(h.request.prompt,
                                         h.request.max_new_tokens)
        else:
            assert h.exception() is not None
    _page_leak_check(srv)


_SNAP_CHAOS = None


def _snap_chaos_server():
    global _SNAP_CHAOS
    if _SNAP_CHAOS is None:
        inj = FaultInjector(seed=0)
        srv = LLMServer(_cfg("recurrentgemma-9b"), num_slots=2, capacity=96,
                        engine_cfg=EngineConfig(cache_mode="paged",
                                                page_size=8, num_snapshots=8,
                                                decode_chunk=4),
                        injector=inj,
                        retry=RetryPolicy(max_attempts=3, backoff_s=1e-3))
        _SNAP_CHAOS = (srv, inj)
    return _SNAP_CHAOS


@given(st.integers(0, 2 ** 16 - 1),
       st.lists(st.tuples(st.integers(0, 2), st.integers(2, 8)),
                min_size=2, max_size=5))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_chaos_snapshots_terminal_and_exactly_once(seed, ops):
    """Snapshot-mode chaos (stateful arch): faults on prefill / extend /
    decode / snapshot restore + arena exhaustion, with session turns in the
    mix — every handle terminal, snapshot ownership exactly-once (a failed
    capture degrades to a skipped capture, never a leak)."""
    srv, inj = _snap_chaos_server()
    inj._rng.seed(seed)
    inj.rates.update({"prefill": 0.04, "extend": 0.06, "decode": 0.06,
                      "snap_restore": 0.08, "snap.alloc": 0.15})
    sess = srv.open_session()
    try:
        handles = []
        for variant, budget in ops:
            if variant == 2 and not sess.busy:
                prompt = (sess.text or _CHAOS_PROMPTS[0]) + " next:"
                handles.append(sess.submit(
                    prompt, SamplingParams(max_new_tokens=budget)))
            else:
                handles.append(srv.submit(
                    _CHAOS_PROMPTS[variant % len(_CHAOS_PROMPTS)],
                    SamplingParams(max_new_tokens=budget)))
            srv.step()
        srv.run_until_idle()
    finally:
        inj.rates.clear()
        srv.run_until_idle()
        sess.close()
    for h in handles:
        assert h.status().terminal
        assert h.status() in (RequestStatus.COMPLETED, RequestStatus.FAILED)
    _snap_leak_check(srv)
